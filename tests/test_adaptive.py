"""Adaptive controller + analytical model (paper §3.3-§4) properties,
including hypothesis property tests on the system's invariants."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # fallback shim, see tests/_hypothesis_fallback.py
    from _hypothesis_fallback import given, settings, st


from repro.core.adaptive import (AdaptiveController, SpeculationLUT,
                                 fixed_controller, lut_from_grid,
                                 lut_from_model)
from repro.core.analytical import (HardwareSpec, LatencyModel,
                                   acceptance_curve, fit_linear_latency,
                                   fit_power_law, power_law_r2,
                                   roofline_latency_model)


# ---------------------------------------------------------------------------
# acceptance curve


@given(st.lists(st.integers(0, 80), min_size=1, max_size=200))
def test_acceptance_curve_properties(runs):
    s_vals = list(range(1, 9))
    ls = acceptance_curve(runs, s_vals)
    assert (ls >= 0).all()
    assert all(a <= b + 1e-12 for a, b in zip(ls, ls[1:]))   # non-decreasing
    assert all(l <= s for l, s in zip(ls, s_vals))           # l(s) <= s
    # concavity of min(l_i, s) means increments shrink
    inc = np.diff(ls)
    assert all(a >= b - 1e-12 for a, b in zip(inc, inc[1:]))


@given(st.floats(0.1, 3.0), st.floats(0.05, 0.95))
@settings(max_examples=30)
def test_power_law_fit_recovers_parameters(c, gamma):
    s = np.arange(1, 9)
    l = c * s ** gamma
    c_, g_ = fit_power_law(s, l)
    assert abs(c_ - c) / c < 1e-6
    assert abs(g_ - gamma) < 1e-6
    assert power_law_r2(s, l, c_, g_) > 0.999999


@given(st.floats(1e-5, 1e-1), st.floats(0.0, 1.0))
@settings(max_examples=30)
def test_linear_fit_recovers(alpha, beta):
    s = np.arange(0, 9)
    a_, b_ = fit_linear_latency(s, alpha * s + beta)
    assert abs(a_ - alpha) < 1e-9 + 1e-6 * alpha
    assert abs(b_ - beta) < 1e-6


# ---------------------------------------------------------------------------
# LUT semantics (paper §4 lookup rule)


def test_lut_lookup_rules():
    lut = SpeculationLUT({1: 6, 4: 4, 16: 2})
    assert lut.lookup(1) == 6 and lut.lookup(4) == 4 and lut.lookup(16) == 2
    assert lut.lookup(2) == min(6, 4) == 4        # smaller of neighbours
    assert lut.lookup(7) == min(4, 2) == 2
    assert lut.lookup(0) == 6 or True             # b<=min clamps
    assert lut.lookup(-1) == 6                    # degenerate clamp low
    assert lut.lookup(999) == 2                   # clamp high
    assert lut.is_monotone()
    assert not SpeculationLUT({1: 2, 4: 5}).is_monotone()


@given(st.dictionaries(st.sampled_from([1, 2, 4, 8, 16, 32]),
                       st.integers(0, 8), min_size=2),
       st.integers(1, 64))
def test_lut_lookup_always_within_observed_range(table, b):
    lut = SpeculationLUT(table)
    s = lut.lookup(b)
    assert min(table.values()) <= s <= max(table.values())


def test_lut_from_grid_argmin():
    grid = {1: {0: 5.0, 2: 3.0, 4: 4.0}, 8: {0: 2.0, 2: 2.5, 4: 3.0}}
    lut = lut_from_grid(grid)
    assert lut.table == {1: 2, 8: 0}


# ---------------------------------------------------------------------------
# analytical model monotonicity (the paper's central theorem)


@given(st.floats(0.3, 1.5), st.floats(0.2, 0.8), st.floats(1e-4, 1e-2),
       st.floats(0.2, 1.5))
@settings(max_examples=40, deadline=None)
def test_s_opt_non_increasing_in_b(c, gamma, beta, slope_pow):
    """For any alpha_b increasing in b (paper's premise), s_opt(b) must be
    non-increasing — Eq. 12's monotonicity argument, checked numerically."""
    batches = (1, 2, 4, 8, 16, 32)
    alpha = {b: 1e-4 * b ** slope_pow for b in batches}
    model = LatencyModel(alpha=alpha, beta={b: beta for b in batches},
                         t_s={b: 2e-5 * (1 + 0.02 * b) for b in batches},
                         c=c, gamma=gamma)
    lut = lut_from_model(model, s_max=8)
    assert lut.is_monotone(), f"LUT {lut.table}"


def test_roofline_model_sane():
    hw = HardwareSpec(chips=4)
    m = roofline_latency_model(7e9, 1.3e8, hw, 0.9, 0.548,
                               cache_bytes_per_seq=1e7)
    for b in m.batch_sizes:
        assert m.per_token_time(b, 0) > 0
        # speculation at s_opt never slower than no speculation
        assert m.per_token_time(b, m.s_opt(b)) <= m.per_token_time(b, 0) + 1e-12


# ---------------------------------------------------------------------------
# controller


def test_controller_choose_and_fixed():
    lut = SpeculationLUT({1: 6, 8: 3, 32: 1})
    ctrl = AdaptiveController(lut=lut)
    assert ctrl.choose(1) == 6 and ctrl.choose(8) == 3 and ctrl.choose(50) == 1
    assert ctrl.choose(0) == 0
    assert fixed_controller(4).choose(17) == 4


def test_controller_online_refresh():
    batches = (1, 2, 4, 8, 16, 32)
    model = LatencyModel(alpha={b: 1e-4 * b for b in batches},
                         beta={b: 5e-3 for b in batches},
                         t_s={b: 2e-5 for b in batches}, c=0.9, gamma=0.5)
    ctrl = AdaptiveController(lut=lut_from_model(model), model=model,
                              ewma_alpha=1.0, drift_threshold=0.2)
    s0 = ctrl.choose(1)
    # feed steps showing near-zero acceptance -> model's c collapses ->
    # optimal s should drop
    for _ in range(5):
        ctrl.observe(np.zeros(4), s=max(ctrl.choose(1), 1))
    assert ctrl.refreshes >= 1
    assert ctrl.choose(1) <= s0
