"""The golden invariant of speculative decoding (paper Algorithm 1): for ANY
speculation length and ANY draft, the committed token stream equals plain
greedy autoregression — speculation may only change *speed*, never *output*.

Covered per architecture family (attention KV rollback, MLA compressed-cache
rollback, SSM/RG-LRU state-checkpoint rollback, enc-dec cross-attention,
VLM prefix offsets), plus acceptance-bound and EOS semantics.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import registry as R
from repro.core.spec_decode import SpecDecodeEngine

# one representative per family (full consistency matrix lives in
# test_models_consistency.py; this file tests the ENGINE on top)
FAMILY_ARCHS = ["yi-9b", "qwen3-moe-30b-a3b", "deepseek-v2-236b",
                "mamba2-1.3b", "recurrentgemma-2b", "paligemma-3b",
                "seamless-m4t-large-v2"]


def _small_draft(tcfg):
    d = R.get_draft_config(tcfg.name.replace("-smoke", ""))
    return dataclasses.replace(
        d, n_layers=1, d_model=64, d_ff=128, vocab_size=tcfg.vocab_size,
        dtype="float32",
        attn=dataclasses.replace(d.attn, n_heads=2, n_kv_heads=2, head_dim=32))


def _engine(arch, max_new=16):
    tcfg = R.get_smoke_config(arch)
    eng = SpecDecodeEngine(tcfg, _small_draft(tcfg), max_new=max_new)
    tp = eng.target.init(jax.random.PRNGKey(0))
    dp = eng.draft.init(jax.random.PRNGKey(1))
    return eng, tp, dp, tcfg


def _extras(cfg, B, eng):
    if cfg.family in ("encdec", "audio"):
        return {"src_embeds": jax.random.normal(jax.random.PRNGKey(7),
                                                (B, 12, cfg.d_model)) * 0.1}
    if cfg.family == "vlm":
        return {"prefix_embeds": jax.random.normal(jax.random.PRNGKey(7),
                                                   (B, cfg.prefix_len, cfg.d_model)) * 0.1}
    return None


@pytest.mark.parametrize("arch", FAMILY_ARCHS)
def test_spec_equals_greedy(arch):
    eng, tp, dp, tcfg = _engine(arch)
    rng = np.random.default_rng(3)
    B = 3
    toks = rng.integers(0, tcfg.vocab_size, (B, 10)).astype(np.int32)
    lens = np.array([10, 7, 9], np.int32)
    kw = _extras(tcfg, B, eng)
    ref, _, _ = eng.generate(tp, dp, toks, lens, s=0, cache_len=96,
                             target_extras=kw)
    for s in (1, 3, 5):
        out, _, _ = eng.generate(tp, dp, toks, lens, s=s, cache_len=96,
                                 target_extras=kw)
        np.testing.assert_array_equal(out, ref, err_msg=f"{arch} s={s}")


@pytest.mark.parametrize("s", [1, 2, 4, 8])
def test_acceptance_bounds_and_progress(s):
    """0 <= accepted <= s and committed == accepted + 1 while not done."""
    eng, tp, dp, tcfg = _engine("yi-9b", max_new=12)
    rng = np.random.default_rng(0)
    toks = rng.integers(0, tcfg.vocab_size, (4, 8)).astype(np.int32)
    lens = np.full((4,), 8, np.int32)
    state = eng.prefill(tp, dp, toks, lens, cache_len=96)
    for _ in range(4):
        prev_done = np.asarray(state.done)
        state, st = eng.step(tp, dp, state, s)
        assert (st.accepted >= 0).all() and (st.accepted <= s).all()
        live = ~prev_done
        np.testing.assert_array_equal(st.committed[live],
                                      np.minimum(st.accepted[live] + 1, 12))
        assert (st.committed[prev_done] == 0).all()


def test_eos_stops_request():
    eng, tp, dp, tcfg = _engine("yi-9b", max_new=32)
    # find the greedy stream, then set eos to its 3rd generated token
    rng = np.random.default_rng(1)
    toks = rng.integers(0, tcfg.vocab_size, (2, 8)).astype(np.int32)
    lens = np.full((2,), 8, np.int32)
    ref, _, _ = eng.generate(tp, dp, toks, lens, s=0, cache_len=96)
    eng2, tp2, dp2 = eng, tp, dp
    eng2.eos_id = int(ref[0, 2])
    # the eos value may already occur earlier in the greedy stream (untrained
    # models repeat); the generation must stop at its FIRST occurrence
    first = int(np.where(ref[0] == eng2.eos_id)[0][0])
    out, _, _ = eng2.generate(tp2, dp2, toks, lens, s=3, cache_len=96)
    gen0 = out[0]
    idx = np.where(gen0 == eng2.eos_id)[0]
    assert len(idx) > 0 and idx[0] == first
    # nothing written after the first eos for that request
    assert (gen0[idx[0] + 1:] == 0).all()
    eng2.eos_id = -1  # restore


def test_max_new_respected():
    eng, tp, dp, tcfg = _engine("yi-9b", max_new=9)
    rng = np.random.default_rng(2)
    toks = rng.integers(0, tcfg.vocab_size, (2, 8)).astype(np.int32)
    lens = np.full((2,), 8, np.int32)
    out, _, _ = eng.generate(tp, dp, toks, lens, s=4, cache_len=96)
    assert out.shape[1] == 9
