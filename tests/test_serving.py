"""Serving layer: traffic statistics, server-loop queueing invariants,
simulation determinism, metrics."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # fallback shim, see tests/_hypothesis_fallback.py
    from _hypothesis_fallback import given, settings, st


from repro.core.adaptive import AdaptiveController, SpeculationLUT, fixed_controller
from repro.core.analytical import LatencyModel
from repro.serving.metrics import batch_size_histogram, summarize, timeline_groups
from repro.serving.server import SimBackend, _match_prob, serve
from repro.serving.traffic import (TrafficPhase, alternating_traffic,
                                   arrival_times, gamma_intervals,
                                   uniform_traffic)


def _model(batches=(1, 2, 4, 8, 16, 32)):
    return LatencyModel(alpha={b: 1e-4 * b ** 0.8 for b in batches},
                        beta={b: 5e-3 for b in batches},
                        t_s={b: 2e-4 for b in batches}, c=0.9, gamma=0.548)


# ---------------------------------------------------------------------------
# traffic


@given(st.floats(0.05, 2.0), st.sampled_from([0.5, 1.0, 2.0, 5.0]))
@settings(max_examples=12, deadline=None)
def test_gamma_interval_statistics(mean, cv):
    rng = np.random.default_rng(0)
    x = gamma_intervals(40_000, mean, cv, rng)
    assert abs(x.mean() - mean) / mean < 0.05
    assert abs(x.std() / x.mean() - cv) / cv < 0.05


def test_arrival_times_monotone_and_phased():
    rng = np.random.default_rng(1)
    at = arrival_times(500, [TrafficPhase(0.1, 1.0, 10.0),
                             TrafficPhase(1.0, 1.0, 10.0)], rng)
    assert (np.diff(at) >= 0).all()
    # intense phases should pack more arrivals per unit time
    in_first = ((at % 20) < 10).sum()
    assert in_first > 0.7 * 500 * (10 / (10 + 1)) * 0.5  # loose sanity


def test_alternating_traffic_request_fields():
    reqs = alternating_traffic(50, vocab=100, seed=0)
    assert len(reqs) == 50
    assert all(r.prompt_len == len(r.tokens) for r in reqs)
    assert all(reqs[i].arrival <= reqs[i + 1].arrival for i in range(49))


# ---------------------------------------------------------------------------
# simulation backend


def test_match_prob_inverts_expected_run():
    for s in (2, 4, 8):
        for l_target in (0.3, 1.0, min(2.5, s - 0.2)):
            p = _match_prob(l_target, s)
            got = sum(p ** i for i in range(1, s + 1))
            assert abs(got - l_target) < 1e-6


def test_sim_backend_deterministic():
    m = _model()
    reqs = uniform_traffic(40, 0.01, 1.0, 100, seed=3, max_new=32)
    r1 = serve([r for r in reqs], SimBackend(m, seed=9), fixed_controller(4))
    reqs2 = uniform_traffic(40, 0.01, 1.0, 100, seed=3, max_new=32)
    r2 = serve([r for r in reqs2], SimBackend(m, seed=9), fixed_controller(4))
    np.testing.assert_allclose(r1.latencies, r2.latencies)


def test_sim_backend_step_accounting():
    m = _model()
    be = SimBackend(m, seed=0)
    reqs = uniform_traffic(8, 0.0, 1.0, 100, seed=0, max_new=16)
    dur, rec = be.run_batch(reqs, s=4)
    assert rec.tokens_generated == 8 * 16
    # duration = n_steps * (t_L + s * t_S) exactly
    step_t = m.t_verify(8, 4) + 4 * m.t_s[8]
    assert abs(dur - rec.n_steps * step_t) < 1e-12
    # speculation needs fewer steps than no-spec
    dur0, rec0 = SimBackend(m, seed=0).run_batch(reqs, s=0)
    assert rec0.n_steps == 16 and rec.n_steps < 16


# ---------------------------------------------------------------------------
# server loop invariants


def test_server_queueing_invariants():
    m = _model()
    reqs = uniform_traffic(60, 0.002, 2.0, 100, seed=5, max_new=32)
    res = serve(reqs, SimBackend(m, seed=1), fixed_controller(2), max_batch=16)
    assert all(r.finish is not None for r in res.requests)
    for r in res.requests:
        assert r.start >= r.arrival - 1e-12          # no time travel
        assert r.finish > r.start
    assert all(b.batch_size <= 16 for b in res.batches)
    # batches execute back-to-back or after an idle gap, never overlapping
    starts = sorted((b.start, b.duration) for b in res.batches)
    for (s1, d1), (s2, _) in zip(starts, starts[1:]):
        assert s2 >= s1 + d1 - 1e-9
    # FIFO: requests are served in arrival order
    order = [r.rid for r in sorted(res.requests, key=lambda r: (r.start, r.arrival))]
    assert order == sorted(order, key=lambda rid: res.requests[rid].arrival)


def test_adaptive_not_worse_than_fixed_in_simulation():
    """End-to-end paper claim at simulation level: adaptive <= best fixed."""
    m = _model()
    from repro.core.adaptive import lut_from_model
    lut = lut_from_model(m, s_max=8)
    means = {}
    for name, ctrl in {
        "s0": fixed_controller(0), "s2": fixed_controller(2),
        "s4": fixed_controller(4), "ad": AdaptiveController(lut=lut),
    }.items():
        tot = 0.0
        for interval in (0.001, 0.01, 0.05):
            reqs = uniform_traffic(150, interval, 1.0, 100, seed=7, max_new=64)
            res = serve(reqs, SimBackend(m, seed=2), ctrl, max_batch=16)
            tot += res.mean_latency
        means[name] = tot
    assert means["ad"] <= min(means["s2"], means["s4"]) * 1.02
    assert means["ad"] < means["s0"]


def test_metrics_shapes():
    m = _model()
    reqs = uniform_traffic(80, 0.01, 1.0, 100, seed=8, max_new=16)
    res = serve(reqs, SimBackend(m, seed=0), fixed_controller(2))
    s = summarize(res)
    assert s.n == 80 and s.p50 <= s.p90 <= s.p99 <= s.max
    tl = timeline_groups(res, group=40)
    assert len(tl) == 2
    hist = batch_size_histogram(res)
    assert sum(k * v for k, v in hist.items()) == 80


def test_continuous_batching_invariants_and_wins_under_load():
    """Iteration-level scheduling must preserve per-request semantics and
    beat run-to-completion at mixed arrival times (beyond-paper fig7)."""
    from repro.serving.server import serve_continuous
    from repro.core.adaptive import lut_from_model
    m = _model()
    lut = lut_from_model(m, s_max=8)
    ctrl = AdaptiveController(lut=lut)
    reqs = uniform_traffic(120, 0.01, 2.0, 100, seed=4, max_new=48)
    res_c = serve_continuous(reqs, m, ctrl, max_batch=16, seed=1)
    assert all(r.finish is not None and r.finish > r.arrival
               for r in res_c.requests)
    total_tokens = sum(b.tokens_generated for b in res_c.batches)
    assert total_tokens == 120 * 48                      # every token served
    assert max(b.batch_size for b in res_c.batches) <= 16
    reqs2 = uniform_traffic(120, 0.01, 2.0, 100, seed=4, max_new=48)
    res_r = serve(reqs2, SimBackend(m, seed=1), ctrl, max_batch=16)
    # head-of-line blocking makes run-to-completion strictly worse here
    assert res_c.mean_latency < res_r.mean_latency
