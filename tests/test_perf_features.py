"""Correctness of the §Perf beyond-paper features: gather-based MoE dispatch,
int8 KV cache, and the one-hot checkpoint commit."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry as R
from repro.core.spec_decode import SpecDecodeEngine
from repro.models.moe import moe_forward


def test_gather_dispatch_equals_einsum():
    cfg = R.get_smoke_config("qwen3-moe-30b-a3b")
    model = R.build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    lp = jax.tree.map(lambda p: p[0], params["layers"]["moe"])
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 16, cfg.d_model))
    y1, a1 = moe_forward(cfg, lp, x)
    cfg2 = cfg.with_(moe=dataclasses.replace(cfg.moe, dispatch="gather"))
    y2, a2 = moe_forward(cfg2, lp, x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(float(a1), float(a2), rtol=1e-5)


def test_gather_dispatch_with_shared_experts():
    cfg = R.get_smoke_config("deepseek-v2-236b")
    model = R.build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    lp = jax.tree.map(lambda p: p[0], params["layers"]["moe"])
    x = jax.random.normal(jax.random.PRNGKey(6), (2, 8, cfg.d_model))
    y1, _ = moe_forward(cfg, lp, x)
    cfg2 = cfg.with_(moe=dataclasses.replace(cfg.moe, dispatch="gather"))
    y2, _ = moe_forward(cfg2, lp, x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-5, atol=2e-5)


def _pair(tcfg):
    dcfg = dataclasses.replace(R.get_smoke_config("internlm2-1.8b"),
                               vocab_size=tcfg.vocab_size)
    eng = SpecDecodeEngine(tcfg, dcfg, max_new=12)
    return (eng, eng.target.init(jax.random.PRNGKey(0)),
            eng.draft.init(jax.random.PRNGKey(1)))


def test_kv_quant_golden_invariant_and_closeness():
    tcfg = R.get_smoke_config("yi-9b")
    rng = np.random.default_rng(0)
    toks = rng.integers(0, tcfg.vocab_size, (2, 10)).astype(np.int32)
    lens = np.array([10, 8], np.int32)
    outs = {}
    for name, cfg in (("fp", tcfg), ("q8", tcfg.with_(kv_quant=True))):
        eng, tp, dp = _pair(cfg)
        ref, _, _ = eng.generate(tp, dp, toks, lens, s=0, cache_len=64)
        spec, _, _ = eng.generate(tp, dp, toks, lens, s=3, cache_len=64)
        np.testing.assert_array_equal(ref, spec)     # golden holds under quant
        outs[name] = ref
    # int8 cache must not change greedy tokens for a smoke-size model
    assert (outs["fp"] == outs["q8"]).mean() > 0.9


def test_kv_quant_prefill_logits_close():
    tcfg = R.get_smoke_config("yi-9b")
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, tcfg.vocab_size, (2, 9)), jnp.int32)
    mq = R.build_model(tcfg.with_(kv_quant=True))
    mf = R.build_model(tcfg)
    params = mf.init(jax.random.PRNGKey(0))
    lq, _, _ = mq.prefill(params, toks, mq.init_cache(2, 64))
    lf, _, _ = mf.prefill(params, toks, mf.init_cache(2, 64))
    assert float(jnp.max(jnp.abs(lq - lf))) < 0.1


@pytest.mark.parametrize("arch", ["mamba2-1.3b", "recurrentgemma-2b"])
def test_onehot_commit_selects_right_checkpoint(arch):
    """commit(accept_idx) must equal stepwise decoding to the same point —
    the invariant behind the GSPMD-friendly one-hot rewrite."""
    cfg = R.get_smoke_config(arch)
    model = R.build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(2)
    B, p, s = 2, 8, 3
    toks = rng.integers(0, cfg.vocab_size, (B, 20)).astype(np.int32)
    if cfg.family == "ssm":
        cache = model.init_cache(B)
    else:
        cache = model.init_cache(B, cache_len=64)
    _, cache, total = model.prefill(params, jnp.asarray(toks[:, :p - 1]), cache)
    seq = total + 1
    feed = jnp.asarray(toks[:, p - 1:p + s])             # s+1 positions
    _, co = model.decode_step(params, feed, cache, seq)
    accept = jnp.array([1, 2], jnp.int32)
    cache_committed = model.commit(co, accept)
    # reference: step one-by-one to each request's accept point... use the
    # max accept for both, then compare only the request that matches
    for b, a in enumerate([1, 2]):
        if cfg.family == "ssm":
            cache_ref = model.init_cache(B)
        else:
            cache_ref = model.init_cache(B, cache_len=64)
        _, cache_ref, tot = model.prefill(params, jnp.asarray(toks[:, :p - 1]),
                                          cache_ref)
        sq = tot + 1
        for i in range(a + 1):
            _, cr = model.decode_step(params, feed[:, i:i + 1], cache_ref, sq)
            cache_ref = model.commit(cr, jnp.zeros((B,), jnp.int32))
            sq = sq + 1
        for k in cache_committed:
            if k in ("k", "v", "pos"):
                continue                                  # ring rows differ ok
            got = np.asarray(cache_committed[k])
            want = np.asarray(cache_ref[k])
            sl = (slice(None), b) if got.ndim > 1 else (b,)
            np.testing.assert_allclose(got[:, b], want[:, b],
                                       rtol=2e-3, atol=2e-3, err_msg=f"{k} b={b}")
