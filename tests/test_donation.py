"""KV pool/cache buffer donation (``SpecDecodeEngine(donate=...)``).

Donation must be a pure aliasing optimization: a full continuous-serving
replay with ``donate=True`` (the default) must be token- and
StepTrace-identical to ``donate=False`` — contiguous, paged, and chunked
admission, plus a sharded 2-device run in a subprocess (forced host
devices must precede jax init).  The semantic edge is pinned directly:
after a real step the *input* pool buffers are deleted under donation
(re-stepping a stale DecodeState is a loud error, not a silent
corruption) and stay alive without it.  graph-lint's donation pass covers
the other half of the contract — that the lowering actually aliases.
"""
import dataclasses
import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.configs import registry as R
from repro.core.adaptive import AdaptiveController, SpeculationLUT
from repro.core.spec_decode import SpecDecodeEngine
from repro.serving.request import Request
from repro.serving.scheduler import (ContinuousEngineBackend,
                                     PrefillBudgetAdmit,
                                     serve_continuous_live)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


@pytest.fixture(scope="module")
def pair():
    tcfg = R.get_smoke_config("yi-9b")
    d = R.get_draft_config("yi-9b")
    dcfg = dataclasses.replace(
        d, n_layers=1, d_model=64, d_ff=128, vocab_size=tcfg.vocab_size,
        dtype="float32",
        attn=dataclasses.replace(d.attn, n_heads=2, n_kv_heads=2,
                                 head_dim=32))
    eng = SpecDecodeEngine(tcfg, dcfg, max_new=10)
    tp = eng.target.init(jax.random.PRNGKey(0))
    dp = eng.draft.init(jax.random.PRNGKey(1))
    return tcfg, dcfg, tp, dp


def _reqs(tcfg, n=5):
    rng = np.random.default_rng(23)
    reqs = []
    for rid in range(n):
        L = int(rng.integers(5, 12))
        toks = rng.integers(0, tcfg.vocab_size, (L,)).astype(np.int32)
        reqs.append(Request(rid=rid, arrival=0.0, tokens=toks, prompt_len=L,
                            max_new=int(rng.integers(4, 9))))
    return reqs


def _serve(pair, donate, mode):
    tcfg, dcfg, tp, dp = pair
    eng = SpecDecodeEngine(tcfg, dcfg, max_new=10, donate=donate)
    bkw = dict(capacity=3, cache_len=32, warm_s=[2, 3], collect_outputs=True)
    policy = None
    if mode in ("paged", "chunked"):
        bkw["block_size"] = 8
    if mode == "chunked":
        policy = PrefillBudgetAdmit(token_budget=6)
    be = ContinuousEngineBackend(eng, tp, dp, **bkw)
    ctrl = AdaptiveController(lut=SpeculationLUT({1: 3, 2: 2, 4: 2}))
    res = serve_continuous_live(_reqs(tcfg), eng, tp, dp, ctrl,
                                backend=be, policy=policy)
    return res, be


@pytest.mark.parametrize("mode", ["contiguous", "paged", "chunked"])
def test_donation_token_and_trace_parity(pair, mode):
    (r0, b0) = _serve(pair, donate=False, mode=mode)
    (r1, b1) = _serve(pair, donate=True, mode=mode)
    t0, t1 = r0.trace, r1.trace
    assert [t.admitted for t in t0] == [t.admitted for t in t1]
    assert [t.occupancy for t in t0] == [t.occupancy for t in t1]
    assert [t.committed for t in t0] == [t.committed for t in t1]
    assert [t.preempted for t in t0] == [t.preempted for t in t1]
    assert [t.done_rids for t in t0] == [t.done_rids for t in t1]
    assert [t.chunked for t in t0] == [t.chunked for t in t1]
    if mode == "chunked":
        assert sum(len(t.chunked) for t in t0) > 0
    assert set(b0.outputs) == set(b1.outputs) and len(b0.outputs) == 5
    for rid in b0.outputs:
        np.testing.assert_array_equal(b0.outputs[rid], b1.outputs[rid],
                                      err_msg=f"{mode} rid {rid}")


def _prefilled_state(pair, donate):
    tcfg, dcfg, tp, dp = pair
    eng = SpecDecodeEngine(tcfg, dcfg, max_new=10, donate=donate)
    state = eng.init_slots(2, 32, block_size=8)
    toks = np.arange(7, dtype=np.int32) % tcfg.vocab_size
    state = eng.prefill_into(tp, dp, state, 0, toks, len(toks), 32)
    return eng, tp, dp, state


def _pool_leaf(state):
    """A KV block-pool leaf of the paged target cache (float k/v arrays;
    the int32 bt/pos tables are rebuilt host-side by the step's block
    bookkeeping, so only the KV pool proper proves the donation)."""
    import jax.numpy as jnp
    return next(x for x in jax.tree.leaves(state.tcache)
                if isinstance(x, jax.Array)
                and jnp.issubdtype(x.dtype, jnp.floating))


def test_donated_input_pool_is_deleted_after_step(pair):
    eng, tp, dp, state = _prefilled_state(pair, donate=True)
    new_state, _ = eng.step(tp, dp, state, 2)
    # the stale input pool was donated into the step: touching it is loud
    with pytest.raises((RuntimeError, ValueError)):
        np.asarray(_pool_leaf(state))
    np.asarray(_pool_leaf(new_state))      # the live pool reads fine


def test_donate_false_keeps_stale_state_readable(pair):
    eng, tp, dp, state = _prefilled_state(pair, donate=False)
    eng.step(tp, dp, state, 2)
    np.asarray(_pool_leaf(state))          # no donation: still alive
    # re-stepping the same stale state is the documented donate=False use
    eng.step(tp, dp, state, 2)


SHARDED_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=2 "
                           + os.environ.get("XLA_FLAGS", ""))
import dataclasses, json
import jax, numpy as np
from repro.configs import registry as R
from repro.core.adaptive import AdaptiveController, SpeculationLUT
from repro.core.spec_decode import SpecDecodeEngine
from repro.launch.mesh import make_serving_mesh
from repro.serving.request import Request
from repro.serving.scheduler import (ContinuousEngineBackend,
                                     serve_continuous_live)

assert jax.device_count() == 2, jax.devices()
tcfg = R.get_smoke_config("yi-9b")
d = R.get_draft_config("yi-9b")
dcfg = dataclasses.replace(
    d, n_layers=1, d_model=64, d_ff=128, vocab_size=tcfg.vocab_size,
    dtype="float32",
    attn=dataclasses.replace(d.attn, n_heads=2, n_kv_heads=2, head_dim=32))
eng0 = SpecDecodeEngine(tcfg, dcfg, max_new=10)
tp = eng0.target.init(jax.random.PRNGKey(0))
dp = eng0.draft.init(jax.random.PRNGKey(1))

def reqs():
    rng = np.random.default_rng(23)
    out = []
    for rid in range(5):
        L = int(rng.integers(5, 12))
        toks = rng.integers(0, tcfg.vocab_size, (L,)).astype(np.int32)
        out.append(Request(rid=rid, arrival=0.0, tokens=toks, prompt_len=L,
                           max_new=int(rng.integers(4, 9))))
    return out

def run(donate):
    eng = SpecDecodeEngine(tcfg, dcfg, max_new=10, donate=donate)
    be = ContinuousEngineBackend(eng, tp, dp, capacity=4, cache_len=32,
                                 warm_s=[2, 3], collect_outputs=True,
                                 mesh=make_serving_mesh(2))
    ctrl = AdaptiveController(lut=SpeculationLUT({1: 3, 2: 2, 4: 2}))
    res = serve_continuous_live(reqs(), eng, tp, dp, ctrl, backend=be)
    return res, be

(r0, b0), (r1, b1) = run(False), run(True)
t0, t1 = r0.trace, r1.trace
assert [t.admitted for t in t0] == [t.admitted for t in t1]
assert [t.committed for t in t0] == [t.committed for t in t1]
assert [t.done_rids for t in t0] == [t.done_rids for t in t1]
assert set(b0.outputs) == set(b1.outputs)
for rid in b0.outputs:
    np.testing.assert_array_equal(b0.outputs[rid], b1.outputs[rid])
assert b1.n_shards == 2
print(json.dumps({"iters": len(t1), "outputs": len(b1.outputs)}))
"""


def test_donation_sharded_parity_two_devices():
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)           # the script forces its own devices
    proc = subprocess.run([sys.executable, "-c", SHARDED_SCRIPT], env=env,
                          capture_output=True, text=True, timeout=1200)
    assert proc.returncode == 0, proc.stderr[-4000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    assert out["iters"] > 0 and out["outputs"] == 5
