"""Per-kernel validation: Pallas (interpret=True on CPU — executes the real
kernel body) vs the pure-jnp oracles in kernels/ref.py, swept over shapes,
dtypes, masking modes, and block sizes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels import ref as R
from repro.kernels.flash_attn import flash_attn_pallas
from repro.kernels.rmsnorm import rmsnorm_pallas
from repro.kernels.spec_verify_attn import spec_verify_attn_pallas
from repro.kernels.ssd_chunk import ssd_chunk_pallas

KEY = jax.random.PRNGKey(0)


def _rand(shape, dtype=jnp.float32, k=0):
    return jax.random.normal(jax.random.fold_in(KEY, k), shape).astype(dtype)


# ---------------------------------------------------------------------------
# rmsnorm


@pytest.mark.parametrize("shape", [(4, 128), (2, 7, 128), (3, 5, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_matches_ref(shape, dtype):
    x = _rand(shape, dtype, 1)
    g = _rand(shape[-1:], jnp.float32, 2)
    got = rmsnorm_pallas(x, g, interpret=True, block_rows=4)
    want = R.rmsnorm_ref(x, g)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=tol, atol=tol)


# ---------------------------------------------------------------------------
# flash attention kernel (folded single-kv-head contract)


@pytest.mark.parametrize("Tq,Tk,hd,bq,bk", [
    (16, 32, 32, 8, 8), (32, 32, 64, 16, 16), (8, 64, 128, 8, 32)])
def test_flash_kernel_causal(Tq, Tk, hd, bq, bk):
    B = 2
    q, k, v = _rand((B, Tq, hd), k=1), _rand((B, Tk, hd), k=2), _rand((B, Tk, hd), k=3)
    qp = jnp.broadcast_to(jnp.arange(Tq, dtype=jnp.int32) + Tk - Tq, (B, Tq))
    kp = jnp.broadcast_to(jnp.arange(Tk, dtype=jnp.int32), (B, Tk))
    got = flash_attn_pallas(q, k, v, qp, kp, block_q=bq, block_k=bk,
                            interpret=True)
    want = R.flash_attn_ref(q, k, v, qp, kp)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("window,prefix", [(None, 0), (8, 0), (None, 6), (8, 6)])
def test_flash_kernel_masking_modes(window, prefix):
    B, Tq, Tk, hd = 2, 16, 48, 32
    q, k, v = _rand((B, Tq, hd), k=4), _rand((B, Tk, hd), k=5), _rand((B, Tk, hd), k=6)
    qp = jnp.broadcast_to(jnp.arange(Tq, dtype=jnp.int32) + 20, (B, Tq))
    kp = jnp.where(jnp.arange(Tk) < 40, jnp.arange(Tk, dtype=jnp.int32), -1)
    kp = jnp.broadcast_to(kp, (B, Tk))
    got = flash_attn_pallas(q, k, v, qp, kp, window=window, prefix_len=prefix,
                            block_q=8, block_k=16, interpret=True)
    want = R.flash_attn_ref(q, k, v, qp, kp, window=window, prefix_len=prefix)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# spec-verify kernel


@pytest.mark.parametrize("Tq", [1, 2, 5, 8])
@pytest.mark.parametrize("L,bk", [(64, 16), (128, 128)])
def test_verify_kernel_vs_ref(Tq, L, bk):
    B, hd = 3, 64
    q, k, v = _rand((B, Tq, hd), k=7), _rand((B, L, hd), k=8), _rand((B, L, hd), k=9)
    seq = 37
    qp = jnp.broadcast_to(jnp.arange(Tq, dtype=jnp.int32) + seq, (B, Tq))
    kp = jnp.where(jnp.arange(L) < seq + Tq, jnp.arange(L, dtype=jnp.int32), -1)
    kp = jnp.broadcast_to(kp, (B, L))
    got = spec_verify_attn_pallas(q, k, v, qp, kp, block_k=bk, interpret=True)
    want = R.spec_verify_ref(q, k, v, qp, kp)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_verify_kernel_tile_skip_matches_windowed_ref():
    """Sliding window: skipped tiles must not change the result."""
    B, Tq, L, hd, w = 2, 4, 256, 32, 32
    q, k, v = _rand((B, Tq, hd), k=10), _rand((B, L, hd), k=11), _rand((B, L, hd), k=12)
    qp = jnp.broadcast_to(jnp.arange(Tq, dtype=jnp.int32) + 200, (B, Tq))
    kp = jnp.broadcast_to(jnp.arange(L, dtype=jnp.int32), (B, L))
    got = spec_verify_attn_pallas(q, k, v, qp, kp, window=w, block_k=32,
                                  interpret=True)
    want = R.spec_verify_ref(q, k, v, qp, kp, window=w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# ops wrappers: GQA folding + ragged positions


@pytest.mark.parametrize("H,KVH", [(8, 8), (8, 2), (4, 1)])
def test_ops_gqa_folding(H, KVH):
    B, T, L, hd = 2, 6, 64, 32
    q = _rand((B, T, H, hd), k=13)
    k = _rand((B, L, KVH, hd), k=14)
    v = _rand((B, L, KVH, hd), k=15)
    lens = jnp.array([50, 33])
    qp = lens[:, None] + jnp.arange(T, dtype=jnp.int32)[None]
    kp = jnp.where(jnp.arange(L)[None] < (lens + T)[:, None],
                   jnp.broadcast_to(jnp.arange(L, dtype=jnp.int32), (B, L)), -1)
    want = ops.spec_verify_attn(q, k, v, qp, kp, use_pallas=False)
    got = ops.spec_verify_attn(q, k, v, qp, kp, use_pallas=True, block_k=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
    wantf = ops.flash_attn(q, k, v, qp, kp, use_pallas=False)
    gotf = ops.flash_attn(q, k, v, qp, kp, use_pallas=True,
                          block_q=8, block_k=16)
    np.testing.assert_allclose(np.asarray(gotf), np.asarray(wantf),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# SSD chunk kernel


@pytest.mark.parametrize("Q,P,N", [(8, 8, 16), (16, 64, 128), (32, 16, 32)])
def test_ssd_chunk_vs_ref(Q, P, N):
    BH = 3
    x = _rand((BH, Q, P), k=16)
    b = _rand((BH, Q, N), k=17) * 0.3
    c = _rand((BH, Q, N), k=18) * 0.3
    dt = jax.nn.softplus(_rand((BH, Q), k=19))
    l = -jax.nn.softplus(_rand((BH, Q), k=20))
    h0 = _rand((BH, P, N), k=21)
    y_p, h_p = ssd_chunk_pallas(x, b, c, dt, l, h0, interpret=True)
    y_r, h_r = jax.vmap(R.ssd_chunk_ref)(x, b, c, dt, l, h0)
    np.testing.assert_allclose(np.asarray(y_p), np.asarray(y_r), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h_p), np.asarray(h_r), rtol=2e-4, atol=2e-4)


def test_ssd_chunk_chains_like_sequential_scan():
    """Two chained chunk calls == one call over the concatenated sequence
    (the inter-chunk recurrence contract the model relies on)."""
    BH, Q, P, N = 2, 8, 8, 16
    x = _rand((BH, 2 * Q, P), k=22)
    b = _rand((BH, 2 * Q, N), k=23) * 0.3
    c = _rand((BH, 2 * Q, N), k=24) * 0.3
    dt = jax.nn.softplus(_rand((BH, 2 * Q), k=25))
    l = -jax.nn.softplus(_rand((BH, 2 * Q), k=26))
    h0 = jnp.zeros((BH, P, N))
    y_full, h_full = ops.ssd_chunk(x, b, c, dt, l, h0, use_pallas=False)
    y1, h1 = ops.ssd_chunk(x[:, :Q], b[:, :Q], c[:, :Q], dt[:, :Q], l[:, :Q],
                           h0, use_pallas=False)
    y2, h2 = ops.ssd_chunk(x[:, Q:], b[:, Q:], c[:, Q:], dt[:, Q:], l[:, Q:],
                           h1, use_pallas=False)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h_full),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# model-level attention helpers agree with each other


def test_train_tri_and_ref_attention_agree():
    from repro.models import common as cm
    B, T, H, KVH, hd = 2, 24, 4, 2, 16
    q = _rand((B, T, H, hd), k=27)
    k = _rand((B, T, KVH, hd), k=28)
    v = _rand((B, T, KVH, hd), k=29)
    pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
    a = cm.flash_attention_tri(q, k, v, pos, pos, block_q=8, block_k=8)
    b_ = cm.flash_attention_train(q, k, v, pos, pos, block_q=8)
    c_ = ops.flash_attn(q, k, v, pos, pos, use_pallas=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b_), rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(a), np.asarray(c_), rtol=2e-5, atol=2e-5)


def test_verify_kernel_int8_dequant_in_vmem():
    """kv_quant path: int8 cache tiles + per-row scales dequantized inside
    the kernel must match the dequantized-reference attention."""
    B, Tq, L, hd = 2, 8, 64, 32
    q = _rand((B, Tq, hd), k=30)
    k = _rand((B, L, hd), k=31)
    v = _rand((B, L, hd), k=32)
    ks = jnp.max(jnp.abs(k), -1) / 127.0
    vs = jnp.max(jnp.abs(v), -1) / 127.0
    kq = jnp.clip(jnp.round(k / ks[..., None]), -127, 127).astype(jnp.int8)
    vq = jnp.clip(jnp.round(v / vs[..., None]), -127, 127).astype(jnp.int8)
    qp = jnp.broadcast_to(jnp.arange(Tq, dtype=jnp.int32) + 40, (B, Tq))
    kp = jnp.broadcast_to(
        jnp.where(jnp.arange(L) < 48, jnp.arange(L, dtype=jnp.int32), -1), (B, L))
    got = spec_verify_attn_pallas(q, kq, vq, qp, kp, k_scale=ks, v_scale=vs,
                                  block_k=16, interpret=True)
    kd = kq.astype(jnp.float32) * ks[..., None]
    vd = vq.astype(jnp.float32) * vs[..., None]
    want = R.spec_verify_ref(q, kd, vd, qp, kp)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
