"""Training substrate: optimizer math, schedule, data pipeline determinism,
loss descent, checkpoint round-trip."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # fallback shim, see tests/_hypothesis_fallback.py
    from _hypothesis_fallback import given, settings, st


from repro.configs import registry as R
from repro.training import (AdamWConfig, DataConfig, batch_at, cross_entropy,
                            init_adamw, lr_schedule, make_train_step, restore,
                            save)
from repro.training.optimizer import adamw_update, global_norm


def test_lr_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_ratio=0.1)
    lrs = [float(lr_schedule(cfg, jnp.asarray(i))) for i in range(0, 101, 5)]
    assert lrs[0] == 0.0
    assert abs(max(lrs) - 1.0) < 0.06          # peak ~lr at warmup end
    assert abs(lrs[-1] - 0.1) < 1e-6           # decays to min ratio
    assert all(a >= b - 1e-9 for a, b in zip(lrs[2:], lrs[3:]))  # then decays


def test_grad_clip_bounds_update():
    cfg = AdamWConfig(lr=1e-2, grad_clip=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros((4,))}
    state = init_adamw(params)
    huge = {"w": jnp.full((4,), 1e6)}
    p2, state, gnorm = adamw_update(cfg, huge, state, params)
    assert float(gnorm) > 1e5
    # after clipping, first-step update magnitude is ~lr
    assert np.all(np.abs(np.asarray(p2["w"])) < cfg.lr * 1.1)


def test_cross_entropy_matches_manual():
    logits = jax.random.normal(jax.random.PRNGKey(0), (2, 5, 7))
    labels = jax.random.randint(jax.random.PRNGKey(1), (2, 5), 0, 7)
    got = float(cross_entropy(logits, labels))
    p = jax.nn.log_softmax(logits, -1)
    want = -float(jnp.take_along_axis(p, labels[..., None], -1).mean())
    assert abs(got - want) < 1e-5


@given(st.integers(0, 1000), st.integers(0, 1000))
@settings(max_examples=10, deadline=None)
def test_data_pipeline_deterministic_and_distinct(i, j):
    dc = DataConfig(vocab_size=64, batch=2, seq_len=16)
    a, b = batch_at(dc, i), batch_at(dc, i)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert a["tokens"].shape == (2, 17)
    assert a["tokens"].max() < 64
    if i != j:
        assert not np.array_equal(batch_at(dc, i)["tokens"],
                                  batch_at(dc, j)["tokens"])


def test_markov_stream_is_learnable_structure():
    """Markov batches must be more predictable than uniform (the property
    the benchmark pair's acceptance depends on)."""
    dc = DataConfig(vocab_size=256, batch=8, seq_len=256, kind="markov",
                    skew=0.9, alphabet=64)
    toks = batch_at(dc, 0)["tokens"]
    # empirical: most frequent successor share >> uniform
    from collections import Counter, defaultdict
    succ = defaultdict(Counter)
    for row in toks:
        for a, b in zip(row[:-1], row[1:]):
            succ[int(a)][int(b)] += 1
    shares = [c.most_common(1)[0][1] / sum(c.values())
              for c in succ.values() if sum(c.values()) >= 10]
    assert np.mean(shares) > 0.5


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "mamba2-1.3b"])
def test_train_step_descends(arch):
    cfg = R.get_smoke_config(arch)
    model = R.build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = AdamWConfig(lr=5e-3, warmup_steps=1, total_steps=30, weight_decay=0.0)
    state = init_adamw(params)
    step = jax.jit(make_train_step(model, cfg, opt), donate_argnums=(0, 1))
    dc = DataConfig(vocab_size=cfg.vocab_size, batch=4, seq_len=32,
                    alphabet=64, skew=0.9)
    losses = []
    for i in range(12):
        params, state, m = step(params, state,
                                {k: jnp.asarray(v) for k, v in batch_at(dc, i % 3).items()})
        assert np.isfinite(float(m["loss"]))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], losses


def test_checkpoint_roundtrip_with_opt_state():
    cfg = R.get_smoke_config("yi-9b")
    model = R.build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    state = init_adamw(params)
    state = state._replace(step=jnp.asarray(17, jnp.int32))
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ckpt.npz")
        save(path, params, state, step=17)
        p2, s2, step = restore(path, params, state)
        assert step == 17 and int(s2.step) == 17
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
