"""Direct unit coverage of serving/metrics.py on hand-built results:
`timeline_groups` partial-tail emission, `mean_occupancy` empty-input
errors, TTFT/ITL skip accounting, `admission_gaps` idle-pool semantics,
`LatencySummary.of` error text, and the serving bench's `goodput`."""
import numpy as np
import pytest

from repro.serving.metrics import (LatencySummary, admission_gaps, goodput,
                                   itl_summary, mean_occupancy, summarize,
                                   timeline_groups, ttft_summary)
from repro.serving.request import BatchRecord, Request
from repro.serving.scheduler import StepTrace
from repro.serving.server import ServeResult


def _req(rid, arrival=0.0, finish=None, first=None, n_gen=0, max_new=8):
    r = Request(rid=rid, arrival=arrival,
                tokens=np.arange(4, dtype=np.int32), prompt_len=4,
                max_new=max_new)
    r.finish = finish
    r.first_token = first
    r.n_generated = n_gen
    return r


def test_latency_summary_empty_names_metric_and_skips():
    with pytest.raises(ValueError, match="no 'ttft' samples"):
        LatencySummary.of([], name="ttft")
    with pytest.raises(ValueError, match=r"3 unfinished/rejected"):
        LatencySummary.of([], name="latency", n_skipped=3)


def test_summarize_skips_unfinished_and_counts_them():
    res = ServeResult(requests=[_req(0, finish=2.0), _req(1), _req(2)],
                      batches=[])
    s = summarize(res)
    assert s.n == 1 and s.n_skipped == 2
    assert s.mean == pytest.approx(2.0)


def test_timeline_groups_emits_partial_tail():
    # 5 finished requests at group=2: two full groups plus a partial tail
    # of 1 (previously the tail was silently dropped)
    reqs = [_req(i, arrival=float(i), finish=float(i) + 1.0 + (i % 2))
            for i in range(5)]
    res = ServeResult(requests=reqs, batches=[])
    tl = timeline_groups(res, group=2)
    assert len(tl) == 3
    assert tl[2][0] == 4.0                 # the tail group's first arrival
    assert tl[2][1] == pytest.approx(reqs[4].latency)
    # fewer requests than one group: one partial group, not an empty list
    tl1 = timeline_groups(res, group=40)
    assert len(tl1) == 1 and tl1[0][0] == 0.0
    # an exact multiple must not grow a phantom empty group
    exact = ServeResult(requests=reqs[:4], batches=[])
    assert len(timeline_groups(exact, group=2)) == 2


def test_mean_occupancy_weights_by_duration_and_raises_when_empty():
    recs = [BatchRecord(start=0.0, duration=3.0, batch_size=4, s_used=2,
                        tokens_generated=10, n_steps=1),
            BatchRecord(start=3.0, duration=1.0, batch_size=8, s_used=2,
                        tokens_generated=10, n_steps=1)]
    res = ServeResult(requests=[], batches=recs)
    assert mean_occupancy(res) == pytest.approx((4 * 3 + 8 * 1) / 4)
    with pytest.raises(ValueError, match="mean_occupancy"):
        mean_occupancy(ServeResult(requests=[_req(0, finish=1.0)],
                                   batches=[]))


def test_ttft_itl_summaries_count_skips():
    reqs = [_req(0, arrival=0.0, first=0.5, finish=2.0, n_gen=4),
            _req(1, arrival=1.0),                        # never scheduled
            _req(2, arrival=0.0, first=0.25, finish=1.0, n_gen=1)]  # no ITL
    res = ServeResult(requests=reqs, batches=[])
    t = ttft_summary(res)
    assert t.n == 2 and t.n_skipped == 1
    assert t.mean == pytest.approx((0.5 + 0.25) / 2)
    i = itl_summary(res)
    assert i.n == 1 and i.n_skipped == 2
    assert i.mean == pytest.approx((2.0 - 0.5) / 3)
    empty = ServeResult(requests=[_req(9)], batches=[])
    with pytest.raises(ValueError, match="first-token"):
        ttft_summary(empty)
    with pytest.raises(ValueError, match="inter-token"):
        itl_summary(empty)


def _tr(clock, rids, admitted=(), duration=0.1, prefill_s=(),
        chunked=(), chunk_s=()):
    return StepTrace(clock=clock, occupancy=len(rids), s=2, rids=rids,
                     committed={r: 1 for r in rids}, admitted=admitted,
                     duration=duration, prefill_s=prefill_s,
                     chunked=chunked, chunk_s=chunk_s)


def test_admission_gaps_skips_idle_pool_first_admission():
    trace = [
        # admission into an idle pool: nobody running yet, no gap
        _tr(0.0, (0,), admitted=(0,), prefill_s=(0.05,)),
        # rid 0 is now decoding: this admission's prefill stalls it
        _tr(0.2, (0, 1), admitted=(1,), prefill_s=(0.04,)),
        # pure decode iteration: no admission work, no gap
        _tr(0.4, (0, 1)),
        # chunked admission (prefill_s = -1 sentinel): only the chunk
        # seconds count as the stall work
        _tr(0.6, (0, 1, 2), admitted=(2,), prefill_s=(-1.0,),
            chunked=((2, 8),), chunk_s=(0.03,)),
    ]
    res = ServeResult(requests=[], batches=[], trace=trace)
    gaps = admission_gaps(res)
    assert gaps == [pytest.approx(0.1 + 0.04), pytest.approx(0.1 + 0.03)]
    with pytest.raises(ValueError, match="StepTrace"):
        admission_gaps(ServeResult(requests=[], batches=[]))


def test_goodput_counts_committed_tokens_over_makespan():
    reqs = [_req(0, arrival=0.0, finish=2.0, n_gen=8),
            _req(1, arrival=1.0, finish=4.0, n_gen=4),
            _req(2, arrival=1.0)]                     # unfinished: excluded
    res = ServeResult(requests=reqs, batches=[])
    assert goodput(res) == pytest.approx(12 / 4.0)
    with pytest.raises(ValueError, match="goodput"):
        goodput(ServeResult(requests=[_req(0)], batches=[]))
