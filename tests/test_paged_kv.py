"""Paged KV block pool: allocator unit tests, paged slot-pool correctness on
a real engine, preemption + re-prefill with sim-vs-live parity, and
regression tests for the slot/engine bugfix sweep (output truncation, KV
overflow rejection, s > S_MAX validation, sync-free retirement)."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import registry as R
from repro.core.adaptive import AdaptiveController, SpeculationLUT
from repro.core.analytical import LatencyModel
from repro.core.spec_decode import S_MAX, SpecDecodeEngine
from repro.serving.request import Request
from repro.serving.scheduler import (ContinuousEngineBackend,
                                     ContinuousScheduler, SimStepBackend,
                                     replay_sources, serve_continuous_live)
from repro.serving.slots import (BlockPool, BlockPoolExhausted, PagedKVTables,
                                 SlotPool)
from repro.serving.traffic import TrafficPhase, make_requests

CACHE_LEN = 96
BLOCK = 8


# ---------------------------------------------------------------------------
# block allocator (host-only, no jax)


def test_block_pool_alloc_free_cycle():
    pool = BlockPool(6, 8)
    assert pool.blocks_for(1) == 1 and pool.blocks_for(8) == 1
    assert pool.blocks_for(9) == 2 and pool.blocks_for(48) == 6
    a = pool.alloc(3)
    assert a == [0, 1, 2]                        # lowest-id-first
    assert pool.free_count == 3 and pool.used_count == 3
    pool.free([1])
    # freed block is reused before higher ids (deterministic placement)
    assert pool.alloc(2) == [1, 3]
    with pytest.raises(BlockPoolExhausted):
        pool.alloc(3)                            # only 2 free
    with pytest.raises(ValueError):
        BlockPool(0, 8)
    with pytest.raises(ValueError):
        BlockPool(4, 0)


def test_block_pool_fragmentation_reuse():
    """Interleaved alloc/free must never lose or duplicate blocks, and holes
    are refilled lowest-first."""
    pool = BlockPool(8, 4)
    a = pool.alloc(4)                            # [0, 1, 2, 3]
    b = pool.alloc(2)                            # [4, 5]
    pool.free([a[0], a[2], b[1]])                # holes at 0, 2, 5
    c = pool.alloc(4)
    assert c == [0, 2, 5, 6]                     # holes first, then fresh
    held = {a[1], a[3], b[0], *c}
    assert len(held) == 7                        # no duplicates handed out
    assert pool.free_count == 1
    pool.free(sorted(held))
    assert pool.free_count == 8
    assert pool.alloc(8) == list(range(8))


def test_paged_tables_lifecycle():
    kv = PagedKVTables(num_blocks=10, block_size=4, capacity=3,
                       max_blocks_per_slot=4)
    assert kv.logical_len == 16
    kv.prefill(0, 7)                             # 2 blocks
    assert kv.allocated(0) == 2 and kv.tokens(0) == 7
    assert kv.free_blocks == 8
    assert kv.ensure(0, 8) == []                 # already covered
    new = kv.ensure(0, 9)                        # grows by one block
    assert len(new) == 1 and kv.allocated(0) == 3
    kv.commit(0, 2)
    assert kv.tokens(0) == 9
    with pytest.raises(RuntimeError):
        kv.prefill(0, 4)                         # double prefill
    with pytest.raises(ValueError):
        kv.prefill(1, 17)                        # over the per-slot cap
    tbl = kv.device_tables()
    assert tbl.shape == (3, 4)
    assert (tbl[0, :3] >= 0).all() and tbl[0, 3] == -1
    assert (tbl[1:] == -1).all()
    freed = kv.release(0)
    assert len(freed) == 3 and kv.free_blocks == 10
    assert kv.active_slots() == []
    # released blocks are reusable by another slot
    kv.prefill(1, 16)
    assert kv.allocated(1) == 4


# ---------------------------------------------------------------------------
# engine-level paged slot pool


@pytest.fixture(scope="module")
def engine():
    tcfg = R.get_smoke_config("yi-9b")
    d = R.get_draft_config("yi-9b")
    dcfg = dataclasses.replace(
        d, n_layers=1, d_model=64, d_ff=128, vocab_size=tcfg.vocab_size,
        dtype="float32",
        attn=dataclasses.replace(d.attn, n_heads=2, n_kv_heads=2, head_dim=32))
    eng = SpecDecodeEngine(tcfg, dcfg, max_new=24)
    tp = eng.target.init(jax.random.PRNGKey(0))
    dp = eng.draft.init(jax.random.PRNGKey(1))
    return eng, tp, dp, tcfg


def _ctrl():
    return AdaptiveController(lut=SpeculationLUT({1: 4, 2: 3, 4: 2}))


def _trace(tcfg, n=12, seed=7, budget=(4, 17)):
    """Rapid-arrival trace; ``budget=(18, 25)`` makes requests outgrow the
    admission-time S_MAX reservation so block pressure (preemption) can
    actually arise mid-flight."""
    reqs = make_requests(n, [TrafficPhase(0.0005, 1.0, float("inf"))],
                         tcfg.vocab_size, seed=seed, max_new=16)
    rng = np.random.default_rng(3)
    for r in reqs:
        r.max_new = int(rng.integers(*budget))
    return reqs


def test_paged_pool_matches_solo_generate(engine):
    """Tokens generated through the paged block pool — including a request
    injected mid-flight and a slot reusing recycled blocks — must equal each
    prompt's solo (contiguous-cache) output."""
    eng, tp, dp, tcfg = engine
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, tcfg.vocab_size, (L,)).astype(np.int32)
               for L in (8, 6, 9)]
    refs = []
    for p in prompts:
        out, _, _ = eng.generate(tp, dp, p[None, :],
                                 np.array([len(p)], np.int32), s=3,
                                 cache_len=CACHE_LEN)
        refs.append(out[0])

    state = eng.init_slots(4, cache_len=CACHE_LEN, block_size=BLOCK)
    assert state.paged is not None
    assert state.paged.num_blocks == 4 * (CACHE_LEN // BLOCK)
    assert bool(np.asarray(state.done).all())
    state = eng.prefill_into(tp, dp, state, 0, prompts[0], len(prompts[0]),
                             CACHE_LEN)
    state = eng.prefill_into(tp, dp, state, 1, prompts[1], len(prompts[1]),
                             CACHE_LEN)
    for _ in range(2):
        state, st = eng.step(tp, dp, state, 3)
        assert (st.committed[2:] == 0).all()     # empty slots stay silent
    state = eng.prefill_into(tp, dp, state, 2, prompts[2], len(prompts[2]),
                             CACHE_LEN)
    for _ in range(40):
        state, _ = eng.step(tp, dp, state, 3)
        if bool(np.asarray(state.done)[:3].all()):
            break
    out = np.asarray(state.out)[:, :eng.max_new]
    for i in range(3):
        np.testing.assert_array_equal(out[i], refs[i], err_msg=f"slot {i}")

    # retire slot 0: its blocks return to the free list and a fresh prompt
    # reuses them without contamination from the previous occupant
    free_before = state.paged.free_blocks
    state = eng.retire_slot(state, 0)
    assert state.paged.free_blocks > free_before
    p = rng.integers(0, tcfg.vocab_size, (7,)).astype(np.int32)
    state = eng.prefill_into(tp, dp, state, 0, p, 7, CACHE_LEN)
    for _ in range(40):
        state, _ = eng.step(tp, dp, state, 3)
        if bool(np.asarray(state.done)[0]):
            break
    ref, _, _ = eng.generate(tp, dp, p[None, :], np.array([7], np.int32),
                             s=3, cache_len=CACHE_LEN)
    np.testing.assert_array_equal(np.asarray(state.out)[0, :eng.max_new],
                                  ref[0])


def test_paged_allocation_is_block_granular(engine):
    """A short prompt holds ceil(prompt/block) blocks after prefill, and the
    table only grows as the sequence crosses block boundaries."""
    eng, tp, dp, tcfg = engine
    state = eng.init_slots(2, cache_len=CACHE_LEN, block_size=BLOCK)
    p = np.arange(6, dtype=np.int32) % tcfg.vocab_size + 1
    state = eng.prefill_into(tp, dp, state, 0, p, 6, CACHE_LEN)
    pk = state.paged
    assert pk.allocated(0) == 1                  # 6 tokens -> 1 block of 8
    state, _ = eng.step(tp, dp, state, 3)
    # step covers seq + s = 9 rows worst case -> exactly 2 blocks
    assert pk.allocated(0) == 2
    assert pk.allocated(1) == 0                  # empty slot never allocates


# ---------------------------------------------------------------------------
# scheduler: preemption + re-prefill


def test_preemption_completes_and_outputs_match_solo(engine):
    """An undersized block pool forces preemption; every request still
    finishes with its full budget and every output — including requests that
    were evicted and re-prefilled — equals the solo greedy continuation."""
    eng, tp, dp, tcfg = engine
    backend = ContinuousEngineBackend(eng, tp, dp, capacity=4,
                                      cache_len=CACHE_LEN, block_size=BLOCK,
                                      num_blocks=18, collect_outputs=True,
                                      warm_s=(2, 3, 4))
    res = serve_continuous_live(_trace(tcfg, budget=(18, 25)), eng, tp, dp,
                                _ctrl(), backend=backend)
    n_preempt = sum(len(t.preempted) for t in res.trace)
    assert n_preempt > 0, "pool was not under pressure; test lost its bite"
    assert all(r.finish is not None for r in res.requests)
    assert all(r.n_generated == r.max_new for r in res.requests)
    preempted = {rid for t in res.trace for rid in t.preempted}
    assert preempted, "no request was preempted"
    for r in res.requests:
        ref, _, _ = eng.generate(tp, dp, np.asarray(r.tokens)[None, :],
                                 np.array([r.prompt_len], np.int32), s=3,
                                 cache_len=CACHE_LEN)
        np.testing.assert_array_equal(
            backend.outputs[r.rid], ref[0][:r.n_generated],
            err_msg=f"rid {r.rid} (preempted={r.rid in preempted})")


def test_preemption_sim_vs_live_parity(engine):
    """The sim backend with the live pool's block geometry must re-derive
    the identical preemption schedule (victims, admissions, occupancies,
    commits) when replaying the live run's outcomes."""
    eng, tp, dp, tcfg = engine
    res = serve_continuous_live(_trace(tcfg, budget=(18, 25)), eng, tp, dp,
                                _ctrl(), capacity=4, cache_len=CACHE_LEN,
                                block_size=BLOCK, num_blocks=18)
    assert sum(len(t.preempted) for t in res.trace) > 0
    accept, duration, prefill, done, _chunk = replay_sources(res.trace)
    bs = (1, 2, 4)
    model = LatencyModel(alpha={b: 1e-4 for b in bs},
                         beta={b: 5e-3 for b in bs},
                         t_s={b: 2e-4 for b in bs}, c=0.9, gamma=0.548)
    sim = ContinuousScheduler(
        SimStepBackend(model, capacity=4, accept_source=accept,
                       duration_source=duration, prefill_source=prefill,
                       done_source=done, block_size=BLOCK, num_blocks=18,
                       max_context=CACHE_LEN),
        _ctrl())
    res_sim = sim.run(_trace(tcfg, budget=(18, 25)))
    assert [t.admitted for t in sim.trace] == [t.admitted for t in res.trace]
    assert [t.preempted for t in sim.trace] == [t.preempted for t in res.trace]
    assert [t.occupancy for t in sim.trace] == [t.occupancy for t in res.trace]
    assert [t.committed for t in sim.trace] == [t.committed for t in res.trace]
    np.testing.assert_allclose(res_sim.latencies, res.latencies, rtol=1e-9)


def test_slot_pool_claim_resumes_preempted_budget():
    pool = SlotPool(2)
    req = Request(rid=0, arrival=0.0, tokens=np.arange(8, dtype=np.int32),
                  prompt_len=8, max_new=16)
    req.n_generated = 5                          # preempted mid-flight
    slot = pool.claim(req)
    assert pool.remaining(slot) == 11            # resumes, not restarts


# ---------------------------------------------------------------------------
# bugfix regressions


def test_output_for_truncates_to_request_budget(engine):
    """A request with max_new smaller than the engine's must not surface
    tokens past its budget (previously output_for returned engine.max_new
    tokens for everyone)."""
    eng, tp, dp, tcfg = engine
    reqs = _trace(tcfg, n=3)
    for r in reqs:
        r.max_new = 5                            # well under engine max_new=24
    backend = ContinuousEngineBackend(eng, tp, dp, capacity=2,
                                      cache_len=CACHE_LEN,
                                      collect_outputs=True, warm_s=(2, 3))
    res = serve_continuous_live(reqs, eng, tp, dp, _ctrl(), backend=backend)
    for r in res.requests:
        assert r.n_generated == 5
        out = backend.outputs[r.rid]
        assert out.shape == (5,)
        ref, _, _ = eng.generate(tp, dp, np.asarray(r.tokens)[None, :],
                                 np.array([r.prompt_len], np.int32), s=3,
                                 cache_len=CACHE_LEN)
        np.testing.assert_array_equal(out, ref[0][:5])


def test_admission_rejects_kv_overflow(engine):
    """prompt_len + max_new + S_MAX beyond the per-request KV capacity must
    be rejected instead of silently wrapping the ring (contiguous) or
    overrunning the block table (paged)."""
    eng, tp, dp, tcfg = engine
    big = _trace(tcfg, n=2)
    big[0] = Request(rid=99, arrival=0.0,
                     tokens=np.ones(CACHE_LEN - 10, np.int32),
                     prompt_len=CACHE_LEN - 10, max_new=20)
    with pytest.raises(ValueError, match="KV"):
        serve_continuous_live(big, eng, tp, dp, _ctrl(), capacity=2,
                              cache_len=CACHE_LEN)
    with pytest.raises(ValueError, match="KV"):
        serve_continuous_live(big, eng, tp, dp, _ctrl(), capacity=2,
                              cache_len=CACHE_LEN, block_size=BLOCK)


def test_step_rejects_s_beyond_smax(engine):
    """s > S_MAX would silently drop committed tokens into the void (the
    out scatter uses mode="drop"); the engine must refuse it loudly."""
    eng, tp, dp, tcfg = engine
    p = np.arange(8, dtype=np.int32) % tcfg.vocab_size + 1
    state = eng.prefill(tp, dp, p[None, :], np.array([8], np.int32),
                        cache_len=CACHE_LEN)
    with pytest.raises(ValueError, match="S_MAX"):
        eng.step(tp, dp, state, S_MAX + 1)
    with pytest.raises(ValueError):
        eng.step(tp, dp, state, -1)


def test_retire_slot_stays_on_device(engine):
    """Retirement must not round-trip device state through the host: the
    done scatter is a jitted device op whose result is a jax array, and
    repeated retirement keeps the remaining slots intact."""
    eng, tp, dp, tcfg = engine
    state = eng.init_slots(3, cache_len=CACHE_LEN)
    p = np.arange(8, dtype=np.int32) % tcfg.vocab_size + 1
    state = eng.prefill_into(tp, dp, state, 0, p, 8, CACHE_LEN)
    state = eng.prefill_into(tp, dp, state, 1, p, 8, CACHE_LEN)
    state = eng.retire_slot(state, 0)
    assert isinstance(state.done, jax.Array)     # no host np.ndarray detour
    done = np.asarray(state.done)
    assert bool(done[0]) and not bool(done[1]) and bool(done[2])
    state = eng.retire_slot(state, 1)
    assert bool(np.asarray(state.done).all())
