"""Minimal stand-in for the ``hypothesis`` API used by this test suite.

When ``hypothesis`` is installed (see requirements-dev.txt) the real library
is used; otherwise test modules fall back to this shim so the suite still
collects and runs everywhere:

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hypothesis_fallback import given, settings, st

The shim samples each strategy deterministically (seeded per test name):
the first examples pin the strategy bounds, the rest are random draws.  It
covers only the strategies this repo uses — floats, integers, booleans,
tuples, sampled_from, lists, dictionaries — with no shrinking; it is a
property *smoke* runner, not a replacement for hypothesis.
"""
from __future__ import annotations

import zlib
from typing import Any, Callable, List, Sequence

import numpy as np

DEFAULT_MAX_EXAMPLES = 25


class _Strategy:
    def example(self, rng: np.random.Generator, idx: int) -> Any:
        raise NotImplementedError


class _Floats(_Strategy):
    def __init__(self, lo: float, hi: float):
        self.lo, self.hi = float(lo), float(hi)

    def example(self, rng, idx):
        if idx == 0:
            return self.lo
        if idx == 1:
            return self.hi
        return float(rng.uniform(self.lo, self.hi))


class _Integers(_Strategy):
    def __init__(self, lo: int, hi: int):
        self.lo, self.hi = int(lo), int(hi)

    def example(self, rng, idx):
        if idx == 0:
            return self.lo
        if idx == 1:
            return self.hi
        return int(rng.integers(self.lo, self.hi + 1))


class _SampledFrom(_Strategy):
    def __init__(self, options: Sequence[Any]):
        self.options = list(options)

    def example(self, rng, idx):
        return self.options[int(rng.integers(len(self.options)))]


class _Lists(_Strategy):
    def __init__(self, elem: _Strategy, min_size: int = 0, max_size: int = 10):
        self.elem, self.min_size, self.max_size = elem, min_size, max_size

    def example(self, rng, idx):
        size = self.min_size if idx == 0 else int(
            rng.integers(self.min_size, self.max_size + 1))
        return [self.elem.example(rng, 2) for _ in range(size)]


class _Booleans(_Strategy):
    def example(self, rng, idx):
        if idx == 0:
            return False
        if idx == 1:
            return True
        return bool(rng.integers(2))


class _Tuples(_Strategy):
    def __init__(self, *elems: _Strategy):
        self.elems = elems

    def example(self, rng, idx):
        return tuple(e.example(rng, idx) for e in self.elems)


class _Dicts(_Strategy):
    def __init__(self, keys: _Strategy, values: _Strategy,
                 min_size: int = 0, max_size: int = 8):
        self.keys, self.values = keys, values
        self.min_size, self.max_size = min_size, max_size

    def example(self, rng, idx):
        out = {}
        target = int(rng.integers(self.min_size, self.max_size + 1))
        for _ in range(50):                      # distinct-key attempts
            if len(out) >= max(target, self.min_size):
                break
            out[self.keys.example(rng, 2)] = self.values.example(rng, 2)
        return out


class _St:
    """The ``strategies`` namespace."""
    @staticmethod
    def floats(min_value, max_value):
        return _Floats(min_value, max_value)

    @staticmethod
    def integers(min_value, max_value):
        return _Integers(min_value, max_value)

    @staticmethod
    def sampled_from(options):
        return _SampledFrom(options)

    @staticmethod
    def booleans():
        return _Booleans()

    @staticmethod
    def tuples(*elements):
        return _Tuples(*elements)

    @staticmethod
    def lists(elements, min_size=0, max_size=10):
        return _Lists(elements, min_size, max_size)

    @staticmethod
    def dictionaries(keys, values, min_size=0, max_size=8):
        return _Dicts(keys, values, min_size, max_size)


st = _St()


def settings(max_examples: int = DEFAULT_MAX_EXAMPLES, **_ignored) -> Callable:
    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn
    return deco


def given(*strategies: _Strategy) -> Callable:
    def deco(fn):
        n = getattr(fn, "_fallback_max_examples", DEFAULT_MAX_EXAMPLES)

        def wrapper(*args, **kwargs):
            rng = np.random.default_rng(zlib.crc32(fn.__name__.encode()))
            for idx in range(n):
                vals = [s.example(rng, idx) for s in strategies]
                try:
                    fn(*args, *vals, **kwargs)
                except Exception as e:
                    raise AssertionError(
                        f"{fn.__name__} failed on example {idx}: {vals!r}"
                    ) from e

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper
    return deco
