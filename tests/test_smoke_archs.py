"""Per-architecture smoke tests (deliverable f): every assigned architecture
instantiates a REDUCED same-family variant and runs one forward + one train
step on CPU, asserting output shapes and finiteness.  Full configs are
exercised only via the allocation-free dry-run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry as R
from repro.configs.base import SHAPES, param_count
from repro.training import AdamWConfig, init_adamw, make_train_step

ARCHS = R.ASSIGNED


def _batch(cfg, B=2, T=16, seed=0):
    rng = np.random.default_rng(seed)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, T + 1)),
                                   jnp.int32)}
    extra = ()
    if cfg.family in ("encdec", "audio"):
        batch["src_embeds"] = jnp.asarray(rng.normal(size=(B, 8, cfg.d_model)) * 0.1,
                                          jnp.float32)
        extra = ("src_embeds",)
    elif cfg.family == "vlm":
        batch["prefix_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.prefix_len, cfg.d_model)) * 0.1, jnp.float32)
        extra = ("prefix_embeds",)
    return batch, extra


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_config_limits(arch):
    cfg = R.get_smoke_config(arch)
    assert cfg.n_layers <= 2 and cfg.d_model <= 512
    if cfg.moe is not None:
        assert cfg.moe.n_experts <= 4
    full = R.get_config(arch)
    assert full.family == cfg.family


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = R.get_smoke_config(arch)
    model = R.build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, T = 2, 16
    batch, _ = _batch(cfg, B, T)
    kw = {k: v for k, v in batch.items() if k != "tokens"}
    logits, aux = model.forward(params, batch["tokens"][:, :-1], **kw)
    expect_t = T + (cfg.prefix_len if cfg.family == "vlm" else 0)
    assert logits.shape == (B, expect_t, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step_no_nans(arch):
    cfg = R.get_smoke_config(arch)
    model = R.build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    state = init_adamw(params)
    batch, extra = _batch(cfg, 2, 16)
    step = jax.jit(make_train_step(model, cfg, opt, extra_keys=extra))
    params2, state2, m = step(params, state, batch)
    assert np.isfinite(float(m["loss"])) and np.isfinite(float(m["grad_norm"]))
    assert int(state2.step) == 1
    # every parameter stays finite and at least one changed
    leaves = jax.tree.leaves(params2)
    assert all(np.isfinite(np.asarray(x)).all() for x in leaves)
    changed = any(not np.array_equal(np.asarray(a), np.asarray(b))
                  for a, b in zip(jax.tree.leaves(params), leaves))
    assert changed


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    """The full config must carry the exact assigned numbers."""
    cfg = R.get_config(arch)
    expect = {
        "qwen3-moe-30b-a3b": dict(n_layers=48, d_model=2048, vocab_size=151936),
        "yi-9b": dict(n_layers=48, d_model=4096, d_ff=11008, vocab_size=64000),
        "seamless-m4t-large-v2": dict(n_layers=24, d_model=1024, d_ff=8192,
                                      vocab_size=256206),
        "paligemma-3b": dict(n_layers=18, d_model=2048, d_ff=16384,
                             vocab_size=257216),
        "mamba2-1.3b": dict(n_layers=48, d_model=2048, vocab_size=50280),
        "qwen3-8b": dict(n_layers=36, d_model=4096, d_ff=12288, vocab_size=151936),
        "recurrentgemma-2b": dict(n_layers=26, d_model=2560, d_ff=7680,
                                  vocab_size=256000),
        "deepseek-v2-236b": dict(n_layers=60, d_model=5120, vocab_size=102400),
        "yi-34b": dict(n_layers=60, d_model=7168, d_ff=20480, vocab_size=64000),
        "internlm2-1.8b": dict(n_layers=24, d_model=2048, d_ff=8192,
                               vocab_size=92544),
    }[arch]
    for k, v in expect.items():
        assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)
    if arch == "qwen3-moe-30b-a3b":
        assert cfg.moe.n_experts == 128 and cfg.moe.top_k == 8
    if arch == "deepseek-v2-236b":
        assert cfg.moe.n_experts == 160 and cfg.moe.top_k == 6
        assert cfg.attn.kv_lora_rank == 512 and cfg.moe.n_shared == 2
    if arch == "mamba2-1.3b":
        assert cfg.ssm.d_state == 128
    # parameter count lands in the right ballpark of the model's name
    n = param_count(cfg)
    expected_scale = {
        "qwen3-moe-30b-a3b": 30e9, "yi-9b": 9e9, "seamless-m4t-large-v2": 2.3e9,
        "paligemma-3b": 2.6e9, "mamba2-1.3b": 1.3e9, "qwen3-8b": 8e9,
        "recurrentgemma-2b": 2.6e9, "deepseek-v2-236b": 236e9, "yi-34b": 34e9,
        "internlm2-1.8b": 1.8e9,
    }[arch]
    assert 0.45 * expected_scale < n < 1.9 * expected_scale, (arch, n)


def test_input_specs_cover_every_pair():
    from repro.launch.specs import input_specs
    for arch in ARCHS:
        for shape in SHAPES:
            specs = input_specs(arch, shape)
            assert specs, (arch, shape)
            for k, v in specs.items():
                assert all(int(d) > 0 for d in v.shape), (arch, shape, k)
