"""In-step chunked prefill: engine-level chunk-vs-whole equality (contiguous
and paged pools), scheduler-level chunked admission with exact outputs and
sim-vs-live StepTrace parity (chunk events replayed), a chunked slot that
later gets preempted, and regressions for the admission/metrics bugfix
sweep (s-ceiling rejection, unfinished-request metrics, empty
LatencySummary, citier zero-collection)."""
import dataclasses
import os
import sys
from collections import Counter

import jax
import numpy as np
import pytest

from repro.configs import registry as R
from repro.core.adaptive import (AdaptiveController, SpeculationLUT,
                                 fixed_controller)
from repro.core.analytical import LatencyModel
from repro.core.spec_decode import S_MAX, SpecDecodeEngine
from repro.serving.metrics import (LatencySummary, admission_gaps, summarize,
                                   timeline_groups)
from repro.serving.request import Request
from repro.serving.scheduler import (ContinuousEngineBackend,
                                     ContinuousScheduler, PrefillBudgetAdmit,
                                     SimStepBackend, controller_s_cap,
                                     replay_sources, serve_continuous_live)
from repro.serving.server import ServeResult
from repro.serving.traffic import TrafficPhase, make_requests

CACHE_LEN = 96
BLOCK = 8


@pytest.fixture(scope="module")
def engine():
    tcfg = R.get_smoke_config("yi-9b")
    d = R.get_draft_config("yi-9b")
    dcfg = dataclasses.replace(
        d, n_layers=1, d_model=64, d_ff=128, vocab_size=tcfg.vocab_size,
        dtype="float32",
        attn=dataclasses.replace(d.attn, n_heads=2, n_kv_heads=2, head_dim=32))
    eng = SpecDecodeEngine(tcfg, dcfg, max_new=24)
    tp = eng.target.init(jax.random.PRNGKey(0))
    dp = eng.draft.init(jax.random.PRNGKey(1))
    return eng, tp, dp, tcfg


def _ctrl():
    return AdaptiveController(lut=SpeculationLUT({1: 4, 2: 3, 4: 2}))


def _model(bs=(1, 2, 4)):
    return LatencyModel(alpha={b: 1e-4 for b in bs},
                        beta={b: 5e-3 for b in bs},
                        t_s={b: 2e-4 for b in bs}, c=0.9, gamma=0.548)


# ---------------------------------------------------------------------------
# engine level: chunked prefill == whole-prompt prefill, token for token


@pytest.mark.parametrize("paged", [False, True], ids=["contiguous", "paged"])
def test_prefill_chunk_into_matches_whole_prefill(engine, paged):
    """A prompt fed across >= 3 chunks — with live decode steps of another
    slot interleaved between the chunks — must produce token-identical
    output to a whole-prompt prefill_into admission, and must not disturb
    the companion slot."""
    eng, tp, dp, tcfg = engine
    rng = np.random.default_rng(3)
    long_p = rng.integers(0, tcfg.vocab_size, (22,)).astype(np.int32)
    short_p = rng.integers(0, tcfg.vocab_size, (7,)).astype(np.int32)
    refs = {}
    for name, p in (("long", long_p), ("short", short_p)):
        out, _, _ = eng.generate(tp, dp, p[None], np.array([len(p)], np.int32),
                                 s=3, cache_len=CACHE_LEN)
        refs[name] = out[0]

    kw = dict(block_size=BLOCK) if paged else {}
    state = eng.init_slots(3, cache_len=CACHE_LEN, **kw)
    state = eng.prefill_into(tp, dp, state, 0, short_p, 7, CACHE_LEN)
    total = len(long_p)
    feed_total = total - 1                       # 21 tokens -> 3 chunks of 8
    cur, n_chunks = 0, 0
    while cur < feed_total:
        m = min(8, feed_total - cur)
        toks = np.ones((8,), np.int32)
        toks[:m] = long_p[cur:cur + m]
        final = cur + m == feed_total
        state = eng.prefill_chunk_into(
            tp, dp, state, 1, toks, cur, m, total,
            last2=long_p[-2:] if final else None)
        cur += m
        n_chunks += 1
        if not final:
            # mid-prefill: the slot stays masked out of the decode step
            state, st = eng.step(tp, dp, state, 3)
            assert st.committed[1] == 0 and st.committed[2] == 0
    assert n_chunks >= 3
    for _ in range(40):
        state, _ = eng.step(tp, dp, state, 3)
        if bool(np.asarray(state.done)[:2].all()):
            break
    out = np.asarray(state.out)[:, :eng.max_new]
    np.testing.assert_array_equal(out[1], refs["long"],
                                  err_msg="chunked slot diverged")
    np.testing.assert_array_equal(out[0], refs["short"],
                                  err_msg="companion slot was disturbed")


def test_prefill_chunk_into_validates_args(engine):
    eng, tp, dp, tcfg = engine
    state = eng.init_slots(2, cache_len=CACHE_LEN)
    toks = np.ones((8,), np.int32)
    with pytest.raises(ValueError, match="bucket"):
        eng.prefill_chunk_into(tp, dp, state, 0, toks, 0, 0, 20)
    with pytest.raises(ValueError, match="overruns"):
        eng.prefill_chunk_into(tp, dp, state, 0, toks, 16, 8, 20)
    with pytest.raises(ValueError, match="last2"):
        # final chunk (start + n == total_len - 1) without last2
        eng.prefill_chunk_into(tp, dp, state, 0, toks, 11, 8, 20)


# ---------------------------------------------------------------------------
# scheduler level: chunked admission, exact outputs, sim-vs-live parity


def _trace(tcfg, n=10, seed=7, long_every=3, long_len=(30, 40),
           budget=(4, 17)):
    reqs = make_requests(n, [TrafficPhase(0.0005, 1.0, float("inf"))],
                         tcfg.vocab_size, seed=seed, max_new=16)
    rng = np.random.default_rng(3)
    for i, r in enumerate(reqs):
        r.max_new = int(rng.integers(*budget))
        if i % long_every == 0:
            L = int(rng.integers(*long_len))
            r.tokens = rng.integers(0, tcfg.vocab_size, (L,)).astype(np.int32)
            r.prompt_len = L
    return reqs


@pytest.mark.parametrize("paged", [False, True], ids=["contiguous", "paged"])
def test_chunked_admission_outputs_and_parity(engine, paged):
    """Long prompts admitted under a 16-token budget are served across >= 3
    chunks with token-identical outputs, the per-iteration admission work
    never exceeds the budget, and a sim backend replaying the recorded
    outcomes reproduces the StepTrace — chunk events included — exactly."""
    eng, tp, dp, tcfg = engine
    kw = dict(block_size=BLOCK, num_blocks=40) if paged else {}
    backend = ContinuousEngineBackend(eng, tp, dp, capacity=4,
                                      cache_len=CACHE_LEN,
                                      collect_outputs=True, warm_s=(2, 3, 4),
                                      **kw)
    pol = PrefillBudgetAdmit(token_budget=16, chunk=8)
    res = serve_continuous_live(_trace(tcfg), eng, tp, dp, _ctrl(),
                                backend=backend, policy=pol)
    assert all(r.finish is not None for r in res.requests)
    assert all(r.n_generated == r.max_new for r in res.requests)
    per_rid = Counter(rid for t in res.trace for rid, _ in t.chunked)
    assert per_rid, "no chunk events recorded"
    assert max(per_rid.values()) >= 3            # a prompt spanned >= 3 chunks
    for t in res.trace:
        assert sum(m for _, m in t.chunked) <= pol.token_budget
    for r in res.requests:
        ref, _, _ = eng.generate(tp, dp, np.asarray(r.tokens)[None, :],
                                 np.array([r.prompt_len], np.int32), s=3,
                                 cache_len=CACHE_LEN)
        np.testing.assert_array_equal(
            backend.outputs[r.rid], ref[0][:r.n_generated],
            err_msg=f"rid {r.rid} ({per_rid.get(r.rid, 0)} chunks)")
    # ---- exact sim-vs-live StepTrace parity, chunk events replayed ----
    accept, duration, prefill, done, chunk = replay_sources(res.trace)
    simkw = (dict(block_size=BLOCK, num_blocks=40, max_context=CACHE_LEN)
             if paged else {})
    sim = ContinuousScheduler(
        SimStepBackend(_model(), capacity=4, accept_source=accept,
                       duration_source=duration, prefill_source=prefill,
                       done_source=done, chunk_source=chunk, **simkw),
        _ctrl(), policy=PrefillBudgetAdmit(token_budget=16, chunk=8))
    res_sim = sim.run(_trace(tcfg))
    for field in ("admitted", "chunked", "occupancy", "committed",
                  "preempted"):
        assert ([getattr(t, field) for t in sim.trace]
                == [getattr(t, field) for t in res.trace]), field
    np.testing.assert_allclose(res_sim.latencies, res.latencies, rtol=1e-9)


def test_chunked_slot_later_preempted(engine):
    """A request admitted chunked, once live, is a normal preemption victim:
    under an undersized block pool it is evicted mid-decode, re-admitted
    (re-chunked from prompt + stash), and still finishes with
    token-identical output; the block-mirror sim re-derives the identical
    schedule."""
    eng, tp, dp, tcfg = engine

    def reqs():
        return _trace(tcfg, n=8, seed=11, long_every=2, long_len=(28, 40),
                      budget=(18, 25))

    backend = ContinuousEngineBackend(eng, tp, dp, capacity=4,
                                      cache_len=CACHE_LEN, block_size=BLOCK,
                                      num_blocks=22, collect_outputs=True,
                                      warm_s=(2, 3, 4))
    pol = PrefillBudgetAdmit(token_budget=16, chunk=8)
    res = serve_continuous_live(reqs(), eng, tp, dp, _ctrl(),
                                backend=backend, policy=pol)
    chunk_rids = {rid for t in res.trace for rid, _ in t.chunked}
    pre_rids = {rid for t in res.trace for rid in t.preempted}
    assert pre_rids, "pool was not under pressure; test lost its bite"
    assert chunk_rids & pre_rids, \
        "no chunk-admitted request was ever preempted"
    assert all(r.finish is not None for r in res.requests)
    assert all(r.n_generated == r.max_new for r in res.requests)
    for r in res.requests:
        ref, _, _ = eng.generate(tp, dp, np.asarray(r.tokens)[None, :],
                                 np.array([r.prompt_len], np.int32), s=3,
                                 cache_len=CACHE_LEN)
        np.testing.assert_array_equal(
            backend.outputs[r.rid], ref[0][:r.n_generated],
            err_msg=f"rid {r.rid} (preempted={r.rid in pre_rids})")
    accept, duration, prefill, done, chunk = replay_sources(res.trace)
    sim = ContinuousScheduler(
        SimStepBackend(_model(), capacity=4, accept_source=accept,
                       duration_source=duration, prefill_source=prefill,
                       done_source=done, chunk_source=chunk, block_size=BLOCK,
                       num_blocks=22, max_context=CACHE_LEN),
        _ctrl(), policy=PrefillBudgetAdmit(token_budget=16, chunk=8))
    res_sim = sim.run(reqs())
    for field in ("admitted", "chunked", "preempted", "occupancy",
                  "committed"):
        assert ([getattr(t, field) for t in sim.trace]
                == [getattr(t, field) for t in res.trace]), field
    np.testing.assert_allclose(res_sim.latencies, res.latencies, rtol=1e-9)


# ---------------------------------------------------------------------------
# sim-only scheduler behaviour (fast, no engine)


def _req(rid, arrival=0.0, plen=8, max_new=16):
    return Request(rid=rid, arrival=arrival,
                   tokens=np.arange(plen, dtype=np.int32) % 97,
                   prompt_len=plen, max_new=max_new)


def test_over_budget_head_admitted_chunked_not_burst():
    """The old PrefillBudgetAdmit escape hatch admitted an over-budget head
    prompt as one whole-prompt burst; with chunking it must enter via
    chunks bounded by the budget, and smaller backlog requests must ride
    along when the chunk size leaves budget to spare."""
    ctrl = fixed_controller(2)
    reqs = [_req(0, plen=64, max_new=8), _req(1, plen=6, max_new=8)]
    sched = ContinuousScheduler(
        SimStepBackend(_model((1, 2, 4, 8)), capacity=4, seed=0),
        ctrl, policy=PrefillBudgetAdmit(token_budget=16, chunk=8))
    sched.run(reqs)
    t0 = sched.trace[0]
    assert t0.admitted == (0, 1)
    # rid 0 entered via a chunk (prefill_s sentinel), rid 1 prefilled whole
    assert t0.prefill_s[0] < 0 and t0.prefill_s[1] >= 0
    assert t0.chunked and t0.chunked[0] == (0, 8)
    # rid 1 starts decoding immediately while rid 0 is still prefilling
    assert t0.occupancy == 1
    # every iteration's admission work stays within the budget
    for t in sched.trace:
        assert sum(m for _, m in t.chunked) <= 16
    # rid 0's chunks eventually complete and it decodes to its full budget
    fed = sum(m for t in sched.trace for rid, m in t.chunked if rid == 0)
    assert fed == 64 - 1                         # feed_total = prompt - 1
    by_rid = {r.rid: r for r in reqs}
    assert by_rid[0].n_generated == 8 and by_rid[0].finish is not None


def test_budget_policy_never_starves_over_budget_prompt():
    """Legacy (chunk-incapable) whole-prompt budgeting: skipping an
    over-budget head in favour of smaller fits must be bounded — a steady
    stream of small prompts cannot defer the long one forever."""
    pol = PrefillBudgetAdmit(token_budget=20, max_defer=5)
    big = _req(0, plen=99)
    for i in range(20):                          # fresh small fit every step
        picked = pol.select([big, _req(100 + i, plen=4)], 2, float(i))
        if big in picked:
            break
    else:
        pytest.fail("over-budget head was starved past max_defer")
    assert i == 5                                # admitted right after aging


def test_chunked_schedule_is_deterministic():
    def run():
        reqs = [_req(i, plen=40 if i % 2 else 8, max_new=12)
                for i in range(6)]
        sched = ContinuousScheduler(
            SimStepBackend(_model((1, 2, 4, 8)), capacity=4, seed=3,
                           block_size=8, num_blocks=30, max_context=96),
            fixed_controller(3),
            policy=PrefillBudgetAdmit(token_budget=12, chunk=6))
        sched.run(reqs)
        return sched.trace
    a, b = run(), run()
    assert [t.chunked for t in a] == [t.chunked for t in b]
    assert [t.admitted for t in a] == [t.admitted for t in b]
    assert [t.occupancy for t in a] == [t.occupancy for t in b]


def test_decode_batch_size_stable_during_chunked_admission():
    """The controller must see the *decode* batch size while a long prompt
    is mid-chunked-prefill — the occupancy the adaptive-s LUT keys on must
    not count PREFILLING slots."""
    ctrl = AdaptiveController(lut=SpeculationLUT({1: 4, 2: 3, 4: 2}))
    reqs = [_req(0, plen=8, max_new=20), _req(1, plen=8, max_new=20),
            _req(2, plen=60, max_new=8)]
    sched = ContinuousScheduler(
        SimStepBackend(_model((1, 2, 4)), capacity=4, seed=0),
        ctrl, policy=PrefillBudgetAdmit(token_budget=20, chunk=10))
    sched.run(reqs)
    feed_total = 60 - 1
    fed = 0
    for t in sched.trace:
        assert t.s == ctrl.choose(t.occupancy)
        fed += sum(m for rid, m in t.chunked if rid == 2)
        # while rid 2 is still mid-prefill it must not count toward the
        # decode occupancy (it joins the batch on its final-chunk step)
        if 0 < fed < feed_total:
            assert t.occupancy <= 2 and 2 not in t.rids


# ---------------------------------------------------------------------------
# bugfix regressions: s-ceiling admission, metrics on unfinished runs


def test_reject_oversize_uses_controller_ceiling_not_smax():
    """A request feasible under the controller's capped speculation length
    must be admitted even though the global S_MAX bound would reject it."""
    # plen + max_new + s: 8 + 16 + 2 = 26 <= 30 < 8 + 16 + 8 = 32
    def run(ctrl):
        reqs = [_req(0, plen=8, max_new=16)]
        sched = ContinuousScheduler(
            SimStepBackend(_model((1, 2, 4, 8)), capacity=2, seed=0,
                           block_size=5, num_blocks=12, max_context=30),
            ctrl)
        return sched.run(reqs)

    res = run(fixed_controller(2))               # capped: feasible
    assert res.requests[0].n_generated == 16
    with pytest.raises(ValueError, match="s_cap"):
        run(fixed_controller(S_MAX))             # uncapped: over capacity
    assert controller_s_cap(fixed_controller(2)) == 2
    assert controller_s_cap(fixed_controller(S_MAX)) == S_MAX
    # the online-refresh controller can rebuild its LUT up to s_max
    c = AdaptiveController(lut=SpeculationLUT({1: 2}), model=_model(),
                           s_max=6)
    assert controller_s_cap(c) == 6


def test_summarize_skips_unfinished_requests():
    done = _req(0); done.finish = 3.0
    hung = _req(1)                               # finish is None
    res = ServeResult(requests=[done, hung], batches=[])
    s = summarize(res)
    assert s.n == 1 and s.n_skipped == 1
    assert s.mean == pytest.approx(3.0)
    with pytest.warns(UserWarning, match="skipping 1"):
        timeline_groups(res, group=1)


def test_latency_summary_empty_raises_clear_error():
    with pytest.raises(ValueError, match="latency"):
        LatencySummary.of([])
    with pytest.raises(ValueError, match="ttft"):
        LatencySummary.of([], name="ttft")
    hung = _req(1)
    with pytest.raises(ValueError, match="unfinished"):
        summarize(ServeResult(requests=[hung], batches=[]))


def test_admission_gaps_requires_trace():
    res = ServeResult(requests=[], batches=[])
    with pytest.raises(ValueError, match="StepTrace"):
        admission_gaps(res)


# ---------------------------------------------------------------------------
# citier: a run that collects zero tests must fail loudly


def test_citier_zero_collection_fails(monkeypatch, tmp_path):
    tools = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "tools")
    sys.path.insert(0, tools)
    try:
        import citier
    finally:
        sys.path.remove(tools)
    monkeypatch.setattr(citier, "check_importable", lambda env: None)
    monkeypatch.setattr(citier.subprocess, "call",
                        lambda *a, **k: citier.EXIT_NO_TESTS_COLLECTED)
    assert citier.main(["fast"]) == 2            # vacuous run is a failure
    monkeypatch.setattr(citier.subprocess, "call", lambda *a, **k: 0)
    assert citier.main(["fast"]) == 0
    # a src tree that cannot provide `repro` is rejected before pytest runs
    monkeypatch.setattr(citier, "ROOT", str(tmp_path))
    with pytest.raises(SystemExit, match="repro"):
        citier.build_env()
