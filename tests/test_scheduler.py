"""Iteration-level scheduler: slot-pool lifecycle, admission policies, and
scheduling invariants on the simulated step backend (fast tier; the live
engine counterparts are in test_continuous_live.py)."""
import numpy as np
import pytest

from repro.core.adaptive import AdaptiveController, fixed_controller, lut_from_model
from repro.core.analytical import LatencyModel
from repro.serving.acceptance import GeometricAcceptance, match_prob
from repro.serving.request import Request
from repro.serving.scheduler import (ContinuousScheduler, FCFSBacklog,
                                     ImmediateAdmit, PrefillBudgetAdmit,
                                     SimStepBackend)
from repro.serving.metrics import (itl_summary, mean_occupancy,
                                   occupancy_timeline, ttft_summary)
from repro.serving.slots import SlotPool
from repro.serving.server import serve_continuous
from repro.serving.traffic import uniform_traffic


def _model(batches=(1, 2, 4, 8, 16, 32)):
    return LatencyModel(alpha={b: 1e-4 * b ** 0.8 for b in batches},
                        beta={b: 5e-3 for b in batches},
                        t_s={b: 2e-4 for b in batches}, c=0.9, gamma=0.548)


def _req(rid, arrival=0.0, plen=8, max_new=16):
    return Request(rid=rid, arrival=arrival,
                   tokens=np.arange(plen, dtype=np.int32), prompt_len=plen,
                   max_new=max_new)


# ---------------------------------------------------------------------------
# slot pool lifecycle


def test_slot_pool_claim_retire_cycle():
    pool = SlotPool(3)
    assert pool.free_count == 3 and pool.occupancy == 0
    r0, r1 = _req(0), _req(1)
    s0, s1 = pool.claim(r0), pool.claim(r1)
    assert (s0, s1) == (0, 1)                      # lowest slot first
    assert pool.occupancy == 2 and pool.free_count == 1
    assert pool.request_at(s1) is r1
    assert pool.remaining(s0) == 16
    pool.consume(s0, 10)
    assert pool.remaining(s0) == 6
    assert pool.retire(s0) is r0
    assert pool.occupancy == 1 and pool.free_count == 2
    # freed slot is reused first
    assert pool.claim(_req(2)) == 0
    assert pool.active_slots() == [0, 1]


def test_slot_pool_errors():
    pool = SlotPool(1)
    pool.claim(_req(0))
    with pytest.raises(RuntimeError):
        pool.claim(_req(1))                        # full
    pool.retire(0)
    with pytest.raises(RuntimeError):
        pool.retire(0)                             # double retire
    with pytest.raises(RuntimeError):
        pool.request_at(0)                         # empty slot
    with pytest.raises(ValueError):
        SlotPool(0)


# ---------------------------------------------------------------------------
# shared acceptance process


def test_geometric_acceptance_matches_expected_run():
    m = _model()
    acc = GeometricAcceptance(m, seed=0)
    for s in (2, 4, 8):
        draws = acc.draw(20000, s)
        assert (draws >= 0).all() and (draws <= s).all()
        assert abs(draws.mean() - m.l_of_s(s)) < 0.05 * max(m.l_of_s(s), 1.0)
    assert acc.draw(5, 0).sum() == 0
    # p-cache inverts the acceptance curve exactly
    for s in (2, 4, 8):
        p = match_prob(m.l_of_s(s), s)
        assert abs(sum(p ** i for i in range(1, s + 1)) - m.l_of_s(s)) < 1e-6


# ---------------------------------------------------------------------------
# admission policies


def test_immediate_admit_fills_free_slots():
    backlog = [_req(i) for i in range(5)]
    assert [r.rid for r in ImmediateAdmit().select(backlog, 3, 0.0)] == [0, 1, 2]
    assert ImmediateAdmit().select(backlog, 0, 0.0) == []


def test_prefill_budget_admission():
    pol = PrefillBudgetAdmit(token_budget=20)
    backlog = [_req(0, plen=12), _req(1, plen=12), _req(2, plen=4)]
    # 12 + 12 > 20: the second 12-token prompt waits, but the 4-token one
    # still fits this step's budget — a too-long prompt must not block
    # smaller backlog requests (the head-of-line fix)
    assert [r.rid for r in pol.select(backlog, 3, 0.0)] == [0, 2]
    # a single over-budget prompt is still admitted (no deadlock) — only on
    # chunk-incapable backends; the scheduler admits it chunked otherwise
    assert [r.rid for r in pol.select([_req(9, plen=99)], 2, 0.0)] == [9]
    # free slots still bound the admission count
    assert [r.rid for r in pol.select(backlog, 1, 0.0)] == [0]
    # an over-budget head never bursts when something smaller fits
    backlog2 = [_req(5, plen=99), _req(6, plen=4)]
    assert [r.rid for r in pol.select(backlog2, 2, 0.0)] == [6]
    with pytest.raises(ValueError):
        PrefillBudgetAdmit(token_budget=0)
    with pytest.raises(ValueError):
        PrefillBudgetAdmit(token_budget=8, chunk=0)


def test_fcfs_backlog_rate_limit():
    pol = FCFSBacklog(max_per_step=2)
    backlog = [_req(i) for i in range(5)]
    assert [r.rid for r in pol.select(backlog, 4, 0.0)] == [0, 1]
    assert [r.rid for r in pol.select(backlog, 1, 0.0)] == [0]


def test_budget_policy_slows_admission_in_scheduler():
    m = _model()
    ctrl = fixed_controller(2)
    reqs = [_req(i, arrival=0.0, plen=16, max_new=8) for i in range(8)]
    sched = ContinuousScheduler(SimStepBackend(m, capacity=8, seed=0), ctrl,
                                policy=PrefillBudgetAdmit(token_budget=16))
    sched.run(reqs)
    # one 16-token prompt per iteration: first step runs at occupancy 1
    assert sched.trace[0].occupancy == 1
    assert all(len(t.admitted) <= 1 for t in sched.trace)
    reqs2 = [_req(i, arrival=0.0, plen=16, max_new=8) for i in range(8)]
    sched2 = ContinuousScheduler(SimStepBackend(m, capacity=8, seed=0), ctrl,
                                 policy=ImmediateAdmit())
    sched2.run(reqs2)
    assert sched2.trace[0].occupancy == 8           # all admitted at once


# ---------------------------------------------------------------------------
# scheduler invariants (sim backend)


def test_scheduler_serves_every_token_and_is_deterministic():
    m = _model()
    lut = lut_from_model(m, s_max=8)
    res = serve_continuous(uniform_traffic(60, 0.01, 2.0, 100, seed=4, max_new=24),
                           m, AdaptiveController(lut=lut), max_batch=8, seed=2)
    assert sum(b.tokens_generated for b in res.batches) == 60 * 24
    assert all(r.finish is not None and r.finish > r.arrival for r in res.requests)
    assert all(r.n_generated == 24 for r in res.requests)
    assert max(b.batch_size for b in res.batches) <= 8
    res2 = serve_continuous(uniform_traffic(60, 0.01, 2.0, 100, seed=4, max_new=24),
                            m, AdaptiveController(lut=lut), max_batch=8, seed=2)
    np.testing.assert_allclose(res.latencies, res2.latencies)
    assert [t.occupancy for t in res.trace] == [t.occupancy for t in res2.trace]


def test_scheduler_chooses_s_from_live_occupancy():
    m = _model()
    lut = lut_from_model(m, s_max=8)
    ctrl = AdaptiveController(lut=lut)
    res = serve_continuous(uniform_traffic(80, 0.005, 2.0, 100, seed=1, max_new=16),
                           m, ctrl, max_batch=16, seed=0)
    for t in res.trace:
        assert t.s == ctrl.choose(t.occupancy)
    # occupancy must actually vary for this to be iteration-level
    occs = {t.occupancy for t in res.trace}
    assert len(occs) > 1


def test_continuous_metrics_ttft_itl_occupancy():
    m = _model()
    res = serve_continuous(uniform_traffic(40, 0.01, 1.0, 100, seed=3, max_new=16),
                           m, fixed_controller(4), max_batch=8, seed=0)
    t = ttft_summary(res)
    assert t.n == 40 and t.mean > 0
    i = itl_summary(res)
    assert i.n == 40 and i.mean > 0
    # TTFT <= total latency for every request
    for r in res.requests:
        assert r.ttft <= r.latency + 1e-12
    occ = mean_occupancy(res)
    assert 1.0 <= occ <= 8.0
    tl = occupancy_timeline(res)
    assert len(tl) == len(res.batches)


def test_sim_backend_preempts_under_block_pressure():
    """With a paged-KV mirror smaller than the trace's aggregate demand the
    scheduler must admit by free blocks, preempt under step pressure
    (longest-remaining, LIFO-admitted victim), and still serve every token;
    the whole schedule is deterministic."""
    m = _model()
    ctrl = fixed_controller(2)

    def reqs():
        return [_req(i, arrival=0.0, plen=16, max_new=24) for i in range(6)]

    def run():
        sched = ContinuousScheduler(
            SimStepBackend(m, capacity=6, seed=0, block_size=8,
                           num_blocks=10, max_context=64), ctrl)
        res = sched.run(reqs())
        return res, sched.trace

    res, trace = run()
    assert all(r.finish is not None for r in res.requests)
    assert all(r.n_generated == 24 for r in res.requests)
    # admission stopped at the free-block budget, not at free slots
    assert len(trace[0].admitted) < 6
    n_pre = sum(len(t.preempted) for t in trace)
    assert n_pre > 0
    res2, trace2 = run()
    assert [t.preempted for t in trace2] == [t.preempted for t in trace]
    assert [t.admitted for t in trace2] == [t.admitted for t in trace]
    np.testing.assert_allclose(res2.latencies, res.latencies)


def test_sim_preemption_replay_parity():
    """Replaying a preempting sim run's outcomes into a second sim with the
    same block geometry reproduces the schedule, preemptions included."""
    from repro.serving.scheduler import replay_sources
    m = _model()
    ctrl = fixed_controller(3)
    reqs = uniform_traffic(20, 0.001, 1.0, 100, seed=9, max_new=18)
    sched = ContinuousScheduler(
        SimStepBackend(m, capacity=4, seed=5, block_size=8, num_blocks=14,
                       max_context=96), ctrl)
    sched.run(reqs)
    ref = sched.trace
    assert sum(len(t.preempted) for t in ref) > 0
    accept, duration, prefill, done, _chunk = replay_sources(ref)
    reqs2 = uniform_traffic(20, 0.001, 1.0, 100, seed=9, max_new=18)
    sched2 = ContinuousScheduler(
        SimStepBackend(m, capacity=4, accept_source=accept,
                       duration_source=duration, prefill_source=prefill,
                       done_source=done, block_size=8, num_blocks=14,
                       max_context=96), ctrl)
    sched2.run(reqs2)
    assert [t.admitted for t in sched2.trace] == [t.admitted for t in ref]
    assert [t.preempted for t in sched2.trace] == [t.preempted for t in ref]
    assert [t.occupancy for t in sched2.trace] == [t.occupancy for t in ref]
    assert [t.committed for t in sched2.trace] == [t.committed for t in ref]


def test_preemption_never_resurrects_done_slot():
    """A slot the backend flagged done (EOS'd, awaiting its zero-commit
    retirement step) must not be chosen as preemption victim even when it
    has the longest remaining budget — evicting it would re-prefill and
    resume a finished request past its EOS."""
    m = _model()
    ctrl = fixed_controller(3)
    # r0 has the longest remaining budget (the default victim); it goes done
    # (EOS) at step 1, and step 2's pressure must evict someone else
    reqs = [_req(0, plen=8, max_new=24), _req(1, plen=8, max_new=16),
            _req(2, plen=8, max_new=16)]

    def accept(step_idx, rids, s):
        return np.array([-1 if (r == 0 and step_idx >= 2) else 3
                         for r in rids])

    def done_src(step_idx):
        return (0,) if step_idx == 1 else ()

    backend = SimStepBackend(m, capacity=3, accept_source=accept,
                             duration_source=lambda i, b, s: 1e-3,
                             prefill_source=lambda rid: 0.0,
                             done_source=done_src, block_size=4,
                             num_blocks=14, max_context=40)
    sched = ContinuousScheduler(backend, ctrl)
    res = sched.run(reqs)
    preempted = [rid for t in sched.trace for rid in t.preempted]
    assert preempted, [t.done_rids for t in sched.trace]
    assert 0 not in preempted, preempted      # the done slot is never evicted
    assert 0 in sched.trace[1].done_rids
    # r0 retired through its zero-commit step with only the pre-EOS tokens;
    # the evicted request was re-prefilled and served its full budget
    by_rid = {r.rid: r for r in res.requests}
    assert by_rid[0].n_generated == 8 and by_rid[0].finish is not None
    for rid in (1, 2):
        assert by_rid[rid].n_generated == 16
        assert by_rid[rid].finish is not None


def test_sim_replay_source_reproduces_schedule():
    """Replaying one sim run's acceptance into a second sim run reproduces
    the admission order and batch-size sequence exactly (the mechanism the
    sim-vs-live parity test uses)."""
    m = _model()
    ctrl = fixed_controller(3)
    reqs = uniform_traffic(30, 0.001, 1.0, 100, seed=9, max_new=12)
    sched = ContinuousScheduler(SimStepBackend(m, capacity=4, seed=5), ctrl)
    sched.run(reqs)
    ref = sched.trace

    def source(step_idx, rids, s):
        rec = ref[step_idx].committed
        return np.array([max(rec[int(r)] - 1, 0) for r in rids])

    reqs2 = uniform_traffic(30, 0.001, 1.0, 100, seed=9, max_new=12)
    sched2 = ContinuousScheduler(
        SimStepBackend(m, capacity=4, accept_source=source), ctrl)
    sched2.run(reqs2)
    assert [t.admitted for t in sched2.trace] == [t.admitted for t in ref]
    assert [t.occupancy for t in sched2.trace] == [t.occupancy for t in ref]
    assert [t.committed for t in sched2.trace] == [t.committed for t in ref]
