"""Iteration-level scheduler: slot-pool lifecycle, admission policies, and
scheduling invariants on the simulated step backend (fast tier; the live
engine counterparts are in test_continuous_live.py)."""
import numpy as np
import pytest

from repro.core.adaptive import AdaptiveController, fixed_controller, lut_from_model
from repro.core.analytical import LatencyModel
from repro.serving.acceptance import GeometricAcceptance, match_prob
from repro.serving.request import Request
from repro.serving.scheduler import (ContinuousScheduler, FCFSBacklog,
                                     ImmediateAdmit, PrefillBudgetAdmit,
                                     SimStepBackend)
from repro.serving.metrics import (itl_summary, mean_occupancy,
                                   occupancy_timeline, ttft_summary)
from repro.serving.slots import SlotPool
from repro.serving.server import serve_continuous
from repro.serving.traffic import uniform_traffic


def _model(batches=(1, 2, 4, 8, 16, 32)):
    return LatencyModel(alpha={b: 1e-4 * b ** 0.8 for b in batches},
                        beta={b: 5e-3 for b in batches},
                        t_s={b: 2e-4 for b in batches}, c=0.9, gamma=0.548)


def _req(rid, arrival=0.0, plen=8, max_new=16):
    return Request(rid=rid, arrival=arrival,
                   tokens=np.arange(plen, dtype=np.int32), prompt_len=plen,
                   max_new=max_new)


# ---------------------------------------------------------------------------
# slot pool lifecycle


def test_slot_pool_claim_retire_cycle():
    pool = SlotPool(3)
    assert pool.free_count == 3 and pool.occupancy == 0
    r0, r1 = _req(0), _req(1)
    s0, s1 = pool.claim(r0), pool.claim(r1)
    assert (s0, s1) == (0, 1)                      # lowest slot first
    assert pool.occupancy == 2 and pool.free_count == 1
    assert pool.request_at(s1) is r1
    assert pool.remaining(s0) == 16
    pool.consume(s0, 10)
    assert pool.remaining(s0) == 6
    assert pool.retire(s0) is r0
    assert pool.occupancy == 1 and pool.free_count == 2
    # freed slot is reused first
    assert pool.claim(_req(2)) == 0
    assert pool.active_slots() == [0, 1]


def test_slot_pool_errors():
    pool = SlotPool(1)
    pool.claim(_req(0))
    with pytest.raises(RuntimeError):
        pool.claim(_req(1))                        # full
    pool.retire(0)
    with pytest.raises(RuntimeError):
        pool.retire(0)                             # double retire
    with pytest.raises(RuntimeError):
        pool.request_at(0)                         # empty slot
    with pytest.raises(ValueError):
        SlotPool(0)


# ---------------------------------------------------------------------------
# shared acceptance process


def test_geometric_acceptance_matches_expected_run():
    m = _model()
    acc = GeometricAcceptance(m, seed=0)
    for s in (2, 4, 8):
        draws = acc.draw(20000, s)
        assert (draws >= 0).all() and (draws <= s).all()
        assert abs(draws.mean() - m.l_of_s(s)) < 0.05 * max(m.l_of_s(s), 1.0)
    assert acc.draw(5, 0).sum() == 0
    # p-cache inverts the acceptance curve exactly
    for s in (2, 4, 8):
        p = match_prob(m.l_of_s(s), s)
        assert abs(sum(p ** i for i in range(1, s + 1)) - m.l_of_s(s)) < 1e-6


# ---------------------------------------------------------------------------
# admission policies


def test_immediate_admit_fills_free_slots():
    backlog = [_req(i) for i in range(5)]
    assert [r.rid for r in ImmediateAdmit().select(backlog, 3, 0.0)] == [0, 1, 2]
    assert ImmediateAdmit().select(backlog, 0, 0.0) == []


def test_prefill_budget_admission():
    pol = PrefillBudgetAdmit(token_budget=20)
    backlog = [_req(0, plen=12), _req(1, plen=12), _req(2, plen=4)]
    # 12 + 12 > 20: second request waits for the next iteration
    assert [r.rid for r in pol.select(backlog, 3, 0.0)] == [0]
    # a single over-budget prompt is still admitted (no deadlock)
    assert [r.rid for r in pol.select([_req(9, plen=99)], 2, 0.0)] == [9]
    # budget is FCFS: it never skips ahead to the small prompt
    assert [r.rid for r in pol.select(backlog, 1, 0.0)] == [0]


def test_fcfs_backlog_rate_limit():
    pol = FCFSBacklog(max_per_step=2)
    backlog = [_req(i) for i in range(5)]
    assert [r.rid for r in pol.select(backlog, 4, 0.0)] == [0, 1]
    assert [r.rid for r in pol.select(backlog, 1, 0.0)] == [0]


def test_budget_policy_slows_admission_in_scheduler():
    m = _model()
    ctrl = fixed_controller(2)
    reqs = [_req(i, arrival=0.0, plen=16, max_new=8) for i in range(8)]
    sched = ContinuousScheduler(SimStepBackend(m, capacity=8, seed=0), ctrl,
                                policy=PrefillBudgetAdmit(token_budget=16))
    sched.run(reqs)
    # one 16-token prompt per iteration: first step runs at occupancy 1
    assert sched.trace[0].occupancy == 1
    assert all(len(t.admitted) <= 1 for t in sched.trace)
    reqs2 = [_req(i, arrival=0.0, plen=16, max_new=8) for i in range(8)]
    sched2 = ContinuousScheduler(SimStepBackend(m, capacity=8, seed=0), ctrl,
                                 policy=ImmediateAdmit())
    sched2.run(reqs2)
    assert sched2.trace[0].occupancy == 8           # all admitted at once


# ---------------------------------------------------------------------------
# scheduler invariants (sim backend)


def test_scheduler_serves_every_token_and_is_deterministic():
    m = _model()
    lut = lut_from_model(m, s_max=8)
    res = serve_continuous(uniform_traffic(60, 0.01, 2.0, 100, seed=4, max_new=24),
                           m, AdaptiveController(lut=lut), max_batch=8, seed=2)
    assert sum(b.tokens_generated for b in res.batches) == 60 * 24
    assert all(r.finish is not None and r.finish > r.arrival for r in res.requests)
    assert all(r.n_generated == 24 for r in res.requests)
    assert max(b.batch_size for b in res.batches) <= 8
    res2 = serve_continuous(uniform_traffic(60, 0.01, 2.0, 100, seed=4, max_new=24),
                            m, AdaptiveController(lut=lut), max_batch=8, seed=2)
    np.testing.assert_allclose(res.latencies, res2.latencies)
    assert [t.occupancy for t in res.trace] == [t.occupancy for t in res2.trace]


def test_scheduler_chooses_s_from_live_occupancy():
    m = _model()
    lut = lut_from_model(m, s_max=8)
    ctrl = AdaptiveController(lut=lut)
    res = serve_continuous(uniform_traffic(80, 0.005, 2.0, 100, seed=1, max_new=16),
                           m, ctrl, max_batch=16, seed=0)
    for t in res.trace:
        assert t.s == ctrl.choose(t.occupancy)
    # occupancy must actually vary for this to be iteration-level
    occs = {t.occupancy for t in res.trace}
    assert len(occs) > 1


def test_continuous_metrics_ttft_itl_occupancy():
    m = _model()
    res = serve_continuous(uniform_traffic(40, 0.01, 1.0, 100, seed=3, max_new=16),
                           m, fixed_controller(4), max_batch=8, seed=0)
    t = ttft_summary(res)
    assert t.n == 40 and t.mean > 0
    i = itl_summary(res)
    assert i.n == 40 and i.mean > 0
    # TTFT <= total latency for every request
    for r in res.requests:
        assert r.ttft <= r.latency + 1e-12
    occ = mean_occupancy(res)
    assert 1.0 <= occ <= 8.0
    tl = occupancy_timeline(res)
    assert len(tl) == len(res.batches)


def test_sim_replay_source_reproduces_schedule():
    """Replaying one sim run's acceptance into a second sim run reproduces
    the admission order and batch-size sequence exactly (the mechanism the
    sim-vs-live parity test uses)."""
    m = _model()
    ctrl = fixed_controller(3)
    reqs = uniform_traffic(30, 0.001, 1.0, 100, seed=9, max_new=12)
    sched = ContinuousScheduler(SimStepBackend(m, capacity=4, seed=5), ctrl)
    sched.run(reqs)
    ref = sched.trace

    def source(step_idx, rids, s):
        rec = ref[step_idx].committed
        return np.array([max(rec[int(r)] - 1, 0) for r in rids])

    reqs2 = uniform_traffic(30, 0.001, 1.0, 100, seed=9, max_new=12)
    sched2 = ContinuousScheduler(
        SimStepBackend(m, capacity=4, accept_source=source), ctrl)
    sched2.run(reqs2)
    assert [t.admitted for t in sched2.trace] == [t.admitted for t in ref]
    assert [t.occupancy for t in sched2.trace] == [t.occupancy for t in ref]
    assert [t.committed for t in sched2.trace] == [t.committed for t in ref]
