"""Cache-consistency invariants: for every architecture family, incremental
decoding through the (ring-buffer / recurrent-state) cache must reproduce the
full-sequence forward logits at the same positions.  This is the substrate
invariant speculative verification relies on.

Engine convention (uniform across attention and recurrent families):
after prefill of a p-token prompt the model state covers positions
0..p-2 (prefill feeds p-1 tokens), n = p tokens are committed, and every
decode step feeds [t_{n-1}, ...] — so recurrent states never double-apply
a token and attention caches satisfy "holds rows 0..n-2".
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry as R

# full-matrix consistency sweeps take >5 minutes; the fast CI tier
# (``pytest -m "not slow"`` / tools/citier.py fast) skips them
pytestmark = pytest.mark.slow

ARCHS = R.ASSIGNED + ["opt-6.7b"]


def _inputs(cfg, B, seed=0):
    kw = {}
    if cfg.family in ("encdec", "audio"):
        kw["src_embeds"] = jax.random.normal(jax.random.PRNGKey(seed + 7),
                                             (B, 12, cfg.d_model)) * 0.1
    elif cfg.family == "vlm":
        kw["prefix_embeds"] = jax.random.normal(jax.random.PRNGKey(seed + 7),
                                                (B, cfg.prefix_len, cfg.d_model)) * 0.1
    return kw


def _setup(arch, B=2, T=24):
    cfg = R.get_smoke_config(arch)
    model = R.build_model(cfg)
    params = model.init(jax.random.PRNGKey(0), jnp.float32)
    tokens = np.array(jax.random.randint(jax.random.PRNGKey(1), (B, T), 0, cfg.vocab_size))
    return cfg, model, params, tokens


def prefill_committed(cfg, model, params, tokens, p, kw, cache_len=64):
    """Prefill the first p tokens under the engine convention; returns
    (cache, seq_lens) with seq_lens = committed count (incl. any prefix)."""
    feed = jnp.asarray(tokens[:, :p - 1])
    if cfg.family in ("encdec", "audio"):
        cache = model.init_cache(tokens.shape[0], cache_len=cache_len,
                                 src_len=kw["src_embeds"].shape[1])
        _, cache, total = model.prefill(params, feed, cache, src_embeds=kw["src_embeds"])
    elif cfg.family == "ssm":
        cache = model.init_cache(tokens.shape[0])
        _, cache, total = model.prefill(params, feed, cache)
    elif cfg.family == "vlm":
        cache = model.init_cache(tokens.shape[0], cache_len=cache_len)
        _, cache, total = model.prefill(params, feed, cache,
                                        prefix_embeds=kw["prefix_embeds"])
    else:
        cache = model.init_cache(tokens.shape[0], cache_len=cache_len)
        _, cache, total = model.prefill(params, feed, cache)
    return cache, total + 1


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch):
    """prefill(prompt) then decode_step(rest) must equal forward() logits."""
    B, T, p = 2, 24, 9
    cfg, model, params, tokens = _setup(arch, B, T)
    kw = _inputs(cfg, B)
    full_logits, _ = model.forward(params, jnp.asarray(tokens), **kw)
    prefix = cfg.prefix_len if (cfg.family == "vlm") else 0

    cache, seq_lens = prefill_committed(cfg, model, params, tokens, p, kw)
    # feed [t_{p-1}, t_p, ..., t_{T-2}] -> logits for positions p-1 .. T-2
    feed = jnp.asarray(tokens[:, p - 1:T - 1])
    logits, _ = model.decode_step(params, feed, cache, seq_lens)
    want = full_logits[:, prefix + p - 1: prefix + T - 1]
    np.testing.assert_allclose(np.asarray(logits), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", ["yi-9b", "mamba2-1.3b", "recurrentgemma-2b",
                                  "deepseek-v2-236b", "seamless-m4t-large-v2",
                                  "paligemma-3b"])
def test_stepwise_decode_matches_block_decode(arch):
    """Token-by-token decoding (with per-step commit) equals one multi-token
    decode step — the rollback/checkpoint machinery is exact."""
    B, T, p = 2, 20, 8
    cfg, model, params, tokens = _setup(arch, B, T)
    kw = _inputs(cfg, B)

    cache, seq_lens = prefill_committed(cfg, model, params, tokens, p, kw)
    feed = jnp.asarray(tokens[:, p - 1:T - 1])
    block_logits, _ = model.decode_step(params, feed, cache, seq_lens)

    cache, seq_lens = prefill_committed(cfg, model, params, tokens, p, kw)
    outs = []
    for i in range(feed.shape[1]):
        logits, cache_out = model.decode_step(params, feed[:, i:i + 1], cache, seq_lens)
        outs.append(np.asarray(logits[:, 0]))
        cache = model.commit(cache_out, jnp.zeros((B,), jnp.int32))
        seq_lens = seq_lens + 1
    np.testing.assert_allclose(np.stack(outs, 1), np.asarray(block_logits),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", ["yi-9b", "mamba2-1.3b", "recurrentgemma-2b"])
def test_commit_rollback_exact(arch):
    """Decode s+1 positions, roll back to an interior acceptance point, and
    check subsequent decoding matches having never speculated at all."""
    B, T, p, s = 2, 22, 8, 4
    cfg, model, params, tokens = _setup(arch, B, T)
    kw = _inputs(cfg, B)

    # speculate with junk drafts, accept a=1 (commit t_{p}), roll back
    cache, seq_lens = prefill_committed(cfg, model, params, tokens, p, kw)
    junk = np.array(tokens[:, p - 1:p + s])        # [B, s+1]
    junk[:, 2:] = (junk[:, 2:] + 1) % cfg.vocab_size  # corrupt drafts after idx 1
    _, cache_out = model.decode_step(params, jnp.asarray(junk), cache, seq_lens)
    accept = jnp.ones((B,), jnp.int32)             # a = 1 accepted draft
    cache = model.commit(cache_out, accept)
    seq_lens = seq_lens + 2                        # a + 1 committed

    # reference: never speculated, decoded the same committed tokens stepwise
    cache_ref, seq_ref = prefill_committed(cfg, model, params, tokens, p, kw)
    for i in range(2):
        _, co = model.decode_step(params, jnp.asarray(tokens[:, p - 1 + i:p + i]),
                                  cache_ref, seq_ref)
        cache_ref = model.commit(co, jnp.zeros((B,), jnp.int32))
        seq_ref = seq_ref + 1

    feed = jnp.asarray(tokens[:, p + 1:T - 1])
    got, _ = model.decode_step(params, feed, cache, seq_lens)
    want, _ = model.decode_step(params, feed, cache_ref, seq_ref)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3, atol=2e-3)
