"""Telemetry hub (serving/telemetry.py): zero overhead when off, the phase
span taxonomy + JSONL event log, the (s, batch) acceptance observatory,
pool/scheduler gauges — and the standing contract that telemetry only
READS the step pipeline: token outputs and the StepTrace are identical
with the hub on or off, on the sim backend and on the live engine across
the contiguous, paged-under-preemption, and chunked-admission paths."""
import dataclasses
import io
import json

import jax
import numpy as np
import pytest

from repro.configs import registry as R
from repro.core.adaptive import (AdaptiveController, SpeculationLUT,
                                 lut_from_model)
from repro.core.analytical import LatencyModel
from repro.core.spec_decode import SpecDecodeEngine
from repro.serving.scheduler import (ContinuousEngineBackend,
                                     ContinuousScheduler, PrefillBudgetAdmit,
                                     SimStepBackend, serve_continuous_live)
from repro.serving.server import serve_continuous
from repro.serving.slots import BlockPool
from repro.serving.telemetry import PHASES, Telemetry
from repro.serving.traffic import TrafficPhase, make_requests, uniform_traffic

CACHE_LEN = 96
BLOCK = 8


def _model(batches=(1, 2, 4, 8, 16)):
    return LatencyModel(alpha={b: 1e-4 * b ** 0.8 for b in batches},
                        beta={b: 5e-3 for b in batches},
                        t_s={b: 2e-4 for b in batches}, c=0.9, gamma=0.548)


# ---------------------------------------------------------------------------
# gauges: BlockPool fragmentation


def test_blockpool_fragmentation_gauge():
    pool = BlockPool(8, 4)
    assert pool.fragmentation == 0.0          # fully free: one run
    blocks = pool.alloc(8)                    # lowest-id first: 0..7
    assert pool.fragmentation == 0.0          # nothing free
    pool.free([blocks[0], blocks[2], blocks[4]])   # {0, 2, 4}: all singles
    assert pool.fragmentation == pytest.approx(1 - 1 / 3)
    pool.free([blocks[1]])                    # {0, 1, 2, 4}: best run is 3
    assert pool.fragmentation == pytest.approx(1 - 3 / 4)
    pool.free([blocks[3], blocks[5], blocks[6], blocks[7]])
    assert pool.fragmentation == 0.0          # whole pool contiguous again


# ---------------------------------------------------------------------------
# sim backend: inertness, parity, spans, observatory, expositions


def test_disabled_telemetry_is_inert():
    m = _model()
    tel = Telemetry(enabled=False)
    sched = ContinuousScheduler(
        SimStepBackend(m, capacity=4, seed=0),
        AdaptiveController(lut=lut_from_model(m, s_max=8)), telemetry=tel)
    # the zero-overhead contract: the scheduler drops a disabled hub
    # entirely, so the hot path never even branches on it
    assert sched._tel is None
    sched.run(uniform_traffic(20, 0.01, 1.0, 100, seed=4, max_new=8))
    assert tel.events == [] and tel.counters == {}
    assert tel.iterations == 0 and tel.acceptance_table() == []
    # direct calls short-circuit too while disabled
    tel.span("prefill", 0, 0.1, rid=1)
    tel.observe_step(s=2, batch=2, accepted=[1, 2], duration=0.1)
    tel.iteration(0, 0.0, occupancy=1)
    assert tel.events == [] and tel.counters == {} and tel.gauges == {}


def test_sim_schedule_identical_with_telemetry_on():
    m = _model()

    def go(tel):
        reqs = uniform_traffic(40, 0.01, 2.0, 100, seed=4, max_new=16)
        return serve_continuous(reqs, m,
                                AdaptiveController(lut=lut_from_model(m)),
                                max_batch=8, seed=2, telemetry=tel)

    r0, r1 = go(None), go(Telemetry())
    for f in ("admitted", "occupancy", "committed", "preempted", "chunked"):
        assert ([getattr(t, f) for t in r0.trace]
                == [getattr(t, f) for t in r1.trace]), f
    np.testing.assert_allclose(r0.latencies, r1.latencies)


def _paged_chunked_sim(tel):
    """Paged + chunked sim run sized to actually preempt (13 blocks of 8
    rows across 4 slots, long prompts every third request)."""
    m = _model()
    ctrl = AdaptiveController(lut=SpeculationLUT({1: 4, 2: 3, 4: 2}))
    reqs = make_requests(10, [TrafficPhase(0.01, 1.0, float("inf"))], 100,
                         seed=3, max_new=12)
    rng = np.random.default_rng(0)
    for j, r in enumerate(reqs):
        r.max_new = int(rng.integers(8, 17))
        if j % 3 == 0:
            L = int(rng.integers(40, 57))
            r.tokens = rng.integers(0, 100, (L,)).astype(np.int32)
            r.prompt_len = L
    sched = ContinuousScheduler(
        SimStepBackend(m, capacity=4, seed=1, block_size=BLOCK,
                       num_blocks=13, max_context=96), ctrl,
        policy=PrefillBudgetAdmit(token_budget=16, chunk=8), telemetry=tel)
    res = sched.run(reqs)
    res.trace = sched.trace
    return res


def test_span_taxonomy_counters_and_jsonl(tmp_path):
    path = tmp_path / "events.jsonl"
    tel = Telemetry(jsonl_path=str(path))
    res = _paged_chunked_sim(tel)
    tel.close()
    trace = res.trace
    lines = [json.loads(l) for l in path.read_text().splitlines()]
    assert lines, "no events streamed"
    spans = [e for e in lines if e["ev"] == "span"]
    assert {e["phase"] for e in spans} <= set(PHASES)
    # counters must reconcile with the StepTrace ground truth
    assert tel.counters["chunk_continue"] == sum(
        len(t.chunked) for t in trace)
    assert tel.counters["preempt"] == sum(len(t.preempted) for t in trace)
    assert tel.counters["preempt"] > 0, "geometry lost its preemption bite"
    assert tel.counters["decode_verify"] == sum(
        1 for t in trace if t.occupancy > 0)
    assert tel.counters["retire"] == len(res.requests)
    assert tel.counters["admit"] == sum(1 for t in trace if t.admitted)
    assert tel.counters.get("prefill", 0) == sum(
        1 for t in trace for dt in t.prefill_s if dt >= 0)
    # the in-memory buffer and the streamed file are the same log
    assert len(tel.events) == len(lines)
    # commit spans accumulate exactly the tokens the requests ended up with
    assert tel.tokens_committed == sum(r.n_generated for r in res.requests)
    # per-span dt totals match the chunk seconds the trace recorded
    chunk_dt = sum(e["dt"] for e in spans if e["phase"] == "chunk_continue")
    assert chunk_dt == pytest.approx(sum(sum(t.chunk_s) for t in trace))


def test_acceptance_observatory_tracks_process():
    m = _model()
    tel = Telemetry()
    tel.attach_expected_acceptance(lambda s: m.l_of_s(s) / s)
    reqs = uniform_traffic(60, 0.005, 1.0, 100, seed=6, max_new=24)
    res = serve_continuous(reqs, m,
                           AdaptiveController(lut=lut_from_model(m)),
                           max_batch=8, seed=1, telemetry=tel)
    table = tel.acceptance_table()
    assert table
    # one draw per live decode row per speculative (s > 0) step
    assert sum(row["draws"] for row in table) == sum(
        t.occupancy for t in res.trace if t.occupancy > 0 and t.s > 0)
    for row in table:
        assert sum(row["hist"]) == row["draws"]
        assert 0.0 <= row["acceptance"] <= 1.0
        assert row["expected"] is not None
    # the sim draws acceptance from the same l(s) the model predicts, so
    # aggregate drift must be small
    drift = tel.acceptance_drift()
    assert drift is not None and abs(drift) < 0.1


def test_gauges_prometheus_and_dashboard():
    stream = io.StringIO()
    tel = Telemetry(dashboard_every=4, stream=stream)
    _paged_chunked_sim(tel)
    g = tel.gauges
    # drained at the end: everything retired, all blocks back on the list
    assert g["occupancy"] == 0 and g["backlog"] == 0
    assert g["free_blocks"] == 13 and g["used_blocks"] == 0
    assert 0.0 <= g["fragmentation"] <= 1.0
    assert tel.peaks["occupancy"] >= 2
    assert tel.peaks["used_blocks"] > 0
    text = tel.prometheus_text()
    assert "repro_serving_occupancy 0" in text
    assert 'repro_serving_spans_total{phase="decode_verify"}' in text
    assert "repro_serving_acceptance_observed{" in text
    assert "repro_serving_peak_occupancy" in text
    dash = tel.dashboard()
    assert "backlog" in dash and "blocks" in dash
    assert stream.getvalue(), "periodic dashboard never printed"
    summ = tel.summary()
    assert summ["counters"] == tel.counters
    assert summ["tokens_committed"] == tel.tokens_committed


# ---------------------------------------------------------------------------
# live engine: token + StepTrace identity with telemetry on vs off


@pytest.fixture(scope="module")
def engine():
    tcfg = R.get_smoke_config("yi-9b")
    d = R.get_draft_config("yi-9b")
    dcfg = dataclasses.replace(
        d, n_layers=1, d_model=64, d_ff=128, vocab_size=tcfg.vocab_size,
        dtype="float32",
        attn=dataclasses.replace(d.attn, n_heads=2, n_kv_heads=2,
                                 head_dim=32))
    eng = SpecDecodeEngine(tcfg, dcfg, max_new=24)
    tp = eng.target.init(jax.random.PRNGKey(0))
    dp = eng.draft.init(jax.random.PRNGKey(1))
    return eng, tp, dp, tcfg


def _ctrl():
    return AdaptiveController(lut=SpeculationLUT({1: 4, 2: 3, 4: 2}))


def _live_trace(tcfg, n=8, seed=7, long_every=0, budget=(4, 17)):
    reqs = make_requests(n, [TrafficPhase(0.002, 1.0, float("inf"))],
                         tcfg.vocab_size, seed=seed, max_new=16)
    rng = np.random.default_rng(3)
    for j, r in enumerate(reqs):
        # arrival = 0: the live clock advances by MEASURED wall times, so
        # nonzero arrivals would make admission composition depend on how
        # fast each run's prefills happened to be — the on-vs-off identity
        # assertion must be purely structural
        r.arrival = 0.0
        r.max_new = int(rng.integers(*budget))
        if long_every and j % long_every == 0:
            L = int(rng.integers(28, 40))
            r.tokens = rng.integers(0, tcfg.vocab_size, (L,)).astype(
                np.int32)
            r.prompt_len = L
    return reqs


# trace/backend/policy per parity case; geometries proven to preempt /
# chunk by tests/test_paged_kv.py and tests/test_chunked_prefill.py
LIVE_CASES = {
    "contiguous": dict(trace={}, backend={}, chunked_policy=False),
    "paged_preempt": dict(trace=dict(budget=(18, 25)),
                          backend=dict(block_size=BLOCK, num_blocks=18),
                          chunked_policy=False),
    "chunked": dict(trace=dict(long_every=3),
                    backend=dict(block_size=BLOCK, num_blocks=40),
                    chunked_policy=True),
}


@pytest.mark.parametrize("case", sorted(LIVE_CASES))
def test_live_token_and_trace_identity_with_telemetry(engine, case,
                                                      tmp_path):
    cfg = LIVE_CASES[case]
    eng, tp, dp, tcfg = engine

    def go(tel):
        be = ContinuousEngineBackend(eng, tp, dp, capacity=4,
                                     cache_len=CACHE_LEN, warm_s=(2, 3, 4),
                                     collect_outputs=True, **cfg["backend"])
        pol = (PrefillBudgetAdmit(token_budget=16, chunk=8)
               if cfg["chunked_policy"] else None)
        res = serve_continuous_live(_live_trace(tcfg, **cfg["trace"]), eng,
                                    tp, dp, _ctrl(), backend=be, policy=pol,
                                    telemetry=tel)
        return res, be

    tel = Telemetry(jsonl_path=str(tmp_path / f"{case}.jsonl"))
    (r0, b0), (r1, b1) = go(None), go(tel)
    tel.close()
    for f in ("admitted", "occupancy", "committed", "preempted",
              "done_rids", "chunked"):
        assert ([getattr(t, f) for t in r0.trace]
                == [getattr(t, f) for t in r1.trace]), f
    assert set(b0.outputs) == set(b1.outputs)
    for rid in b0.outputs:
        np.testing.assert_array_equal(b0.outputs[rid], b1.outputs[rid],
                                      err_msg=f"{case} rid {rid}")
    # each case exercised the machinery it claims to cover
    if case == "paged_preempt":
        assert tel.counters["preempt"] > 0
        assert sum(len(t.preempted) for t in r1.trace) > 0
    if case == "chunked":
        assert tel.counters["chunk_continue"] > 0
        assert sum(len(t.chunked) for t in r1.trace) > 0
    assert tel.counters["retire"] == len(r1.requests)
    assert tel.tokens_committed == sum(r.n_generated for r in r1.requests)


def test_device_annotation_scopes_run_and_reset(engine):
    """annotate_device=True routes every jit dispatch through a
    TraceAnnotation scope (a no-op outside an active profiler trace) and
    the engine flag is restored after the run."""
    eng, tp, dp, tcfg = engine
    assert eng.annotate is False
    tel = Telemetry(annotate_device=True)
    res = serve_continuous_live(_live_trace(tcfg, n=4), eng, tp, dp,
                                _ctrl(), capacity=2, cache_len=CACHE_LEN,
                                telemetry=tel)
    assert all(r.finish is not None for r in res.requests)
    assert tel.iterations == len(res.trace)
    assert eng.annotate is False          # restored after the run
